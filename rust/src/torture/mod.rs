//! The hash-torture benchmarking framework (paper §6.1, extending
//! perfbook's `hashtorture`).
//!
//! A run spawns `threads` workers, each performing a random mix of
//! lookup / insert / delete operations (distribution `m`) over keys drawn
//! uniformly from `[0, key_range)`, optionally alongside a *rebuilder*
//! thread that continuously rebuilds the table between two sizes (the
//! §6.2 protocol: same hash function on both sides, which degrades the
//! dynamic tables to resizable ones so HT-Split can be compared fairly).
//!
//! The average load factor α is controlled the way the paper does it:
//! prefill `α · β` nodes and keep the insert ratio equal to the delete
//! ratio so the population stays put in expectation.

pub mod workload;
pub mod zipf;

pub use workload::{
    run_elastic, AttackGen, ElasticReport, ElasticTortureConfig, OpMix, ShardedAttackGen,
};
pub use zipf::Zipf;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crossbeam_utils::CachePadded;

use crate::baselines::ConcurrentMap;
use crate::dhash::HashFn;
use crate::rcu::RcuThread;
use crate::util::affinity;
use crate::util::SplitMix64;

/// Rebuilder behaviour during a torture run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RebuildMode {
    /// No rebuilds: measures the table's steady-state common-op path.
    None,
    /// Continuously rebuild between `nbuckets` and `alt_nbuckets` with
    /// the *same* hash function (paper §6.2).
    Continuous { alt_nbuckets: usize },
}

/// One torture-run configuration (the paper's parameters m, α, β, U).
#[derive(Clone, Debug)]
pub struct TortureConfig {
    /// Worker thread count.
    pub threads: usize,
    /// Operation mix `m` (lookup percentage; the rest splits evenly
    /// between insert and delete).
    pub mix: OpMix,
    /// Average load factor α: prefill is `alpha * nbuckets` nodes.
    pub alpha: usize,
    /// Bucket count β of the initial table.
    pub nbuckets: usize,
    /// Key range U (paper: 10,000,000). `0` = auto: U = 2·α·β, the
    /// value at which uniform-random inserts and deletes *balance*
    /// (insert succeeds w.p. 1 - n/U, delete w.p. n/U; equilibrium is
    /// n = U/2), keeping the population stationary at exactly α·β. The
    /// paper's fixed U drifts toward U/2 over long windows; see
    /// EXPERIMENTS.md §Fig2 notes.
    pub key_range: u64,
    /// Measurement window.
    pub duration: Duration,
    pub rebuild: RebuildMode,
    /// Pin workers round-robin to cores (performance-first mapping).
    pub pin: bool,
    /// Workload PRNG seed (runs are reproducible given a seed).
    pub seed: u64,
    /// Hash seed shared by old/new tables under Continuous rebuild.
    pub hash_seed: u64,
}

/// True when the CI bench-smoke knob is set: `DHASH_SMOKE=1` shrinks
/// durations and thread counts across the bench harness so a full
/// `cargo bench` sweep is a compile-and-run sanity gate (< 2 min), with
/// no performance meaning.
pub fn smoke_mode() -> bool {
    std::env::var("DHASH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

impl TortureConfig {
    /// U, resolving `0` to the stationary value 2·α·β.
    pub fn resolved_key_range(&self) -> u64 {
        if self.key_range == 0 {
            2 * (self.alpha * self.nbuckets) as u64
        } else {
            self.key_range
        }
    }

    /// Clamp this configuration for the CI smoke gate. A no-op unless
    /// [`smoke_mode`] is set; under it, runs are capped at 2 threads and
    /// a 60 ms measurement window.
    pub fn clamped_for_smoke(mut self) -> Self {
        if smoke_mode() {
            self.threads = self.threads.min(2);
            self.duration = self.duration.min(Duration::from_millis(60));
        }
        self
    }
}

impl Default for TortureConfig {
    fn default() -> Self {
        Self {
            threads: 2,
            mix: OpMix::lookup_pct(90),
            alpha: 20,
            nbuckets: 1024,
            key_range: 1_000_000,
            duration: Duration::from_millis(500),
            rebuild: RebuildMode::Continuous { alt_nbuckets: 2048 },
            pin: true,
            seed: 0xd1e5_5eed,
            hash_seed: 0x5eed,
        }
    }
}

/// Result of one torture run.
#[derive(Clone, Debug)]
pub struct TortureReport {
    pub table: &'static str,
    /// Total completed operations across workers.
    pub total_ops: u64,
    pub per_thread_ops: Vec<u64>,
    /// Completed rebuilds during the window.
    pub rebuilds: u64,
    pub elapsed: Duration,
}

impl TortureReport {
    /// Throughput in million operations per second (the paper's y-axis).
    pub fn mops(&self) -> f64 {
        self.total_ops as f64 / self.elapsed.as_secs_f64() / 1e6
    }
}

/// Prefill `alpha * nbuckets` distinct keys so the measured phase starts
/// at the target load factor. Returns the number inserted.
pub fn prefill(map: &dyn ConcurrentMap, cfg: &TortureConfig) -> u64 {
    let g = RcuThread::register();
    let target = (cfg.alpha * cfg.nbuckets) as u64;
    let key_range = cfg.resolved_key_range();
    assert!(
        target <= key_range / 2,
        "key range too small for target population (α·β = {target}, U = {key_range})"
    );
    let mut rng = SplitMix64::new(cfg.seed ^ 0xf1ff);
    let mut n = 0;
    while n < target {
        let k = rng.next_bounded(key_range);
        if map.insert(&g, k, k) {
            n += 1;
        }
        if n % 1024 == 0 {
            g.quiescent_state();
        }
    }
    g.quiescent_state();
    n
}

/// Run one torture measurement (prefill NOT included; call [`prefill`]).
pub fn run(map: Arc<dyn ConcurrentMap>, cfg: &TortureConfig) -> TortureReport {
    let stop = Arc::new(AtomicBool::new(false));
    let counters: Arc<Vec<CachePadded<AtomicU64>>> = Arc::new(
        (0..cfg.threads)
            .map(|_| CachePadded::new(AtomicU64::new(0)))
            .collect(),
    );
    let rebuilds = Arc::new(AtomicU64::new(0));

    let mut handles = Vec::with_capacity(cfg.threads + 1);
    for t in 0..cfg.threads {
        let map = map.clone();
        let stop = stop.clone();
        let counters = counters.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            if cfg.pin {
                affinity::pin_next();
            }
            let key_range = cfg.resolved_key_range();
            let g = RcuThread::register();
            let mut rng = SplitMix64::new(cfg.seed.wrapping_add(t as u64 * 0x9e37));
            let mut local = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // Batch 64 ops between stop-flag checks and counter
                // publication to keep the hot loop tight.
                for _ in 0..64 {
                    let key = rng.next_bounded(key_range);
                    match cfg.mix.pick(&mut rng) {
                        workload::Op::Lookup => {
                            std::hint::black_box(map.lookup(&g, key));
                        }
                        workload::Op::Insert => {
                            std::hint::black_box(map.insert(&g, key, key));
                        }
                        workload::Op::Delete => {
                            std::hint::black_box(map.delete(&g, key));
                        }
                        workload::Op::Upsert => {
                            std::hint::black_box(map.upsert(&g, key, key));
                        }
                    }
                    local += 1;
                }
                g.quiescent_state();
                counters[t].store(local, Ordering::Relaxed);
            }
            g.offline();
        }));
    }

    // Optional continuous rebuilder (not counted as a worker).
    let rebuilder = match cfg.rebuild {
        RebuildMode::None => None,
        RebuildMode::Continuous { alt_nbuckets } => {
            let map = map.clone();
            let stop = stop.clone();
            let rebuilds = rebuilds.clone();
            let cfg = cfg.clone();
            Some(std::thread::spawn(move || {
                let g = RcuThread::register();
                let hash = HashFn::Seeded(cfg.hash_seed);
                let mut flip = false;
                while !stop.load(Ordering::Relaxed) {
                    let nb = if flip { cfg.nbuckets } else { alt_nbuckets };
                    flip = !flip;
                    if map.rebuild(&g, nb, hash) {
                        rebuilds.fetch_add(1, Ordering::Relaxed);
                    }
                    g.quiescent_state();
                }
                g.offline();
            }))
        }
    };

    let t0 = Instant::now();
    std::thread::sleep(cfg.duration);
    stop.store(true, Ordering::Relaxed);
    let elapsed = t0.elapsed();
    for h in handles {
        h.join().unwrap();
    }
    if let Some(h) = rebuilder {
        h.join().unwrap();
    }

    let per_thread_ops: Vec<u64> = counters.iter().map(|c| c.load(Ordering::Relaxed)).collect();
    TortureReport {
        table: map.name(),
        total_ops: per_thread_ops.iter().sum(),
        per_thread_ops,
        rebuilds: rebuilds.load(Ordering::Relaxed),
        elapsed,
    }
}

/// Convenience: prefill + `repeats` measured runs, returning Mop/s
/// samples (the benches feed these into `util::stats::Summary`).
pub fn measure_mops(
    map: Arc<dyn ConcurrentMap>,
    cfg: &TortureConfig,
    repeats: usize,
) -> Vec<f64> {
    prefill(&*map, cfg);
    (0..repeats).map(|_| run(map.clone(), cfg).mops()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{HtRht, HtSplit, HtXu};
    use crate::dhash::DHashMap;
    use crate::rcu::rcu_barrier;

    fn tiny_cfg() -> TortureConfig {
        TortureConfig {
            threads: 2,
            mix: OpMix::lookup_pct(80),
            alpha: 4,
            nbuckets: 64,
            key_range: 0, // auto: stationary 2·α·β
            duration: Duration::from_millis(120),
            rebuild: RebuildMode::Continuous { alt_nbuckets: 128 },
            pin: false,
            seed: 7,
            hash_seed: 3,
        }
    }

    #[test]
    fn smoke_clamp_caps_threads_and_duration() {
        // Unset: clamping is a no-op.
        std::env::remove_var("DHASH_SMOKE");
        let cfg = TortureConfig {
            threads: 16,
            duration: Duration::from_secs(5),
            ..tiny_cfg()
        };
        let same = cfg.clone().clamped_for_smoke();
        assert_eq!(same.threads, 16);
        assert_eq!(same.duration, Duration::from_secs(5));
        // Set: threads and window shrink to smoke scale.
        std::env::set_var("DHASH_SMOKE", "1");
        let small = cfg.clamped_for_smoke();
        std::env::remove_var("DHASH_SMOKE");
        assert!(small.threads <= 2);
        assert!(small.duration <= Duration::from_millis(60));
    }

    #[test]
    fn prefill_reaches_target_population() {
        let cfg = tiny_cfg();
        let map: Arc<dyn ConcurrentMap> = Arc::new(DHashMap::with_buckets(cfg.nbuckets, 3));
        let n = prefill(&*map, &cfg);
        assert_eq!(n, (cfg.alpha * cfg.nbuckets) as u64);
        let g = RcuThread::register();
        assert_eq!(map.len(&g), n as usize);
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn run_produces_ops_and_rebuilds_dhash() {
        let cfg = tiny_cfg();
        let map: Arc<dyn ConcurrentMap> = Arc::new(DHashMap::with_buckets(cfg.nbuckets, 3));
        prefill(&*map, &cfg);
        let rep = run(map, &cfg);
        assert!(rep.total_ops > 1000, "ops {}", rep.total_ops);
        assert!(rep.rebuilds > 0, "no rebuilds completed");
        assert!(rep.mops() > 0.0);
        assert_eq!(rep.per_thread_ops.len(), 2);
        rcu_barrier();
    }

    #[test]
    fn run_produces_ops_and_rebuilds_sharded() {
        // Same bucket budget as tiny_cfg, split over 4 shards; the trait
        // rebuild path exercises the staggered rebuild_all under load.
        let cfg = tiny_cfg();
        let map: Arc<dyn ConcurrentMap> =
            Arc::new(crate::dhash::ShardedDHash::with_buckets(4, cfg.nbuckets / 4, 3));
        prefill(&*map, &cfg);
        let rep = run(map, &cfg);
        assert_eq!(rep.table, "HT-DHash-Sharded");
        assert!(rep.total_ops > 1000, "ops {}", rep.total_ops);
        assert!(rep.rebuilds > 0, "no staggered rebuilds completed");
        rcu_barrier();
    }

    #[test]
    fn run_with_upsert_mix() {
        // The serving-shaped mix: part of the read share becomes
        // last-wins upserts, exercising the atomic overwrite path under
        // continuous rebuilds.
        let cfg = TortureConfig {
            mix: OpMix::with_upserts(80, 30),
            duration: Duration::from_millis(100),
            ..tiny_cfg()
        };
        let map: Arc<dyn ConcurrentMap> = Arc::new(DHashMap::with_buckets(cfg.nbuckets, 3));
        prefill(&*map, &cfg);
        let rep = run(map, &cfg);
        assert!(rep.total_ops > 500, "ops {}", rep.total_ops);
        rcu_barrier();
    }

    #[test]
    fn run_all_baselines_smoke() {
        let cfg = TortureConfig {
            duration: Duration::from_millis(80),
            ..tiny_cfg()
        };
        let tables: Vec<Arc<dyn ConcurrentMap>> = vec![
            Arc::new(HtXu::new(cfg.nbuckets, HashFn::Seeded(cfg.hash_seed))),
            Arc::new(HtRht::new(cfg.nbuckets, HashFn::Seeded(cfg.hash_seed))),
            Arc::new(HtSplit::new(cfg.nbuckets, 1 << 20)),
        ];
        for map in tables {
            prefill(&*map, &cfg);
            let rep = run(map.clone(), &cfg);
            assert!(rep.total_ops > 500, "{}: {}", rep.table, rep.total_ops);
        }
        rcu_barrier();
    }

    #[test]
    fn population_stays_near_target() {
        // insert% == delete% keeps the population stable in expectation.
        let cfg = TortureConfig {
            duration: Duration::from_millis(250),
            ..tiny_cfg()
        };
        let map: Arc<dyn ConcurrentMap> = Arc::new(DHashMap::with_buckets(cfg.nbuckets, 3));
        let target = prefill(&*map, &cfg) as f64;
        run(map.clone(), &cfg);
        let g = RcuThread::register();
        let after = map.len(&g) as f64;
        assert!(
            (after - target).abs() / target < 0.5,
            "population drifted: {target} -> {after}"
        );
        g.quiescent_state();
        rcu_barrier();
    }
}
