//! Zipfian key generator (rejection-inversion sampling, Hörmann &
//! Derflinger 1996 — the method used by YCSB and rand_distr). Real KV
//! workloads are heavily skewed; the paper's "bursts of incoming data"
//! motivation is modeled by high-s zipf traffic in the
//! `fragment_reassembly` example.

use crate::util::SplitMix64;

/// Zipf(n, s) sampler over `{1, ..., n}` (rank 1 is the hottest).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_integral_x1: f64,
    h_integral_n: f64,
    s_const: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1, "zipf needs n >= 1");
        assert!(
            s > 0.0 && s != 1.0,
            "exponent must be > 0 and != 1 (use ~1.0001 near 1)"
        );
        let mut z = Self {
            n,
            s,
            h_integral_x1: 0.0,
            h_integral_n: 0.0,
            s_const: 0.0,
        };
        // The -1.0 extends the inversion domain to cover rank 1 (the
        // area of the leftmost bar, h(1) = 1) — Apache commons'
        // RejectionInversionZipfSampler does the same.
        z.h_integral_x1 = z.h_integral(1.5) - 1.0;
        z.h_integral_n = z.h_integral(n as f64 + 0.5);
        z.s_const = 2.0 - z.h_integral_inv(z.h_integral(2.5) - z.h(2.0));
        z
    }

    /// H(x) = (x^(1-s) - 1) / (1-s), computed stably via expm1/ln.
    #[inline]
    fn h_integral(&self, x: f64) -> f64 {
        ((1.0 - self.s) * x.ln()).exp_m1() / (1.0 - self.s)
    }

    /// Inverse of `h_integral`.
    #[inline]
    fn h_integral_inv(&self, x: f64) -> f64 {
        (((1.0 - self.s) * x).ln_1p() / (1.0 - self.s)).exp()
    }

    /// h(x) = x^(-s).
    #[inline]
    fn h(&self, x: f64) -> f64 {
        (-self.s * x.ln()).exp()
    }

    /// Draw one rank in `[1, n]`.
    pub fn sample(&self, rng: &mut SplitMix64) -> u64 {
        loop {
            let p = rng.next_f64();
            let u = self.h_integral_n + p * (self.h_integral_x1 - self.h_integral_n);
            let x = self.h_integral_inv(u);
            let k = x.round().clamp(1.0, self.n as f64);
            if k - x <= self.s_const || u >= self.h_integral(k + 0.5) - self.h(k) {
                return k as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = SplitMix64::new(5);
        for _ in 0..10_000 {
            let k = z.sample(&mut rng);
            assert!((1..=1000).contains(&k));
        }
    }

    #[test]
    fn skew_concentrates_on_head() {
        let z = Zipf::new(10_000, 1.2);
        let mut rng = SplitMix64::new(6);
        let n = 50_000;
        let head = (0..n).filter(|_| z.sample(&mut rng) <= 10).count();
        // With s=1.2 the top-10 ranks should absorb a large share.
        assert!(
            head as f64 / n as f64 > 0.3,
            "head share too small: {head}/{n}"
        );
    }

    #[test]
    fn low_skew_is_spread_out() {
        let z = Zipf::new(1000, 0.5);
        let mut rng = SplitMix64::new(7);
        let n = 50_000;
        let head = (0..n).filter(|_| z.sample(&mut rng) <= 10).count();
        assert!(
            (head as f64) / (n as f64) < 0.3,
            "low-skew head share too large: {head}/{n}"
        );
    }

    #[test]
    fn rank_frequencies_decrease() {
        let z = Zipf::new(50, 1.5);
        let mut rng = SplitMix64::new(8);
        let mut counts = [0u32; 51];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[1] > counts[2]);
        assert!(counts[2] > counts[5]);
        assert!(counts[5] > counts[20]);
    }

    #[test]
    fn deterministic_per_seed() {
        let z = Zipf::new(100, 1.1);
        let mut a = SplitMix64::new(9);
        let mut b = SplitMix64::new(9);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut a), z.sample(&mut b));
        }
    }

    #[test]
    #[should_panic(expected = "exponent")]
    fn rejects_s_equal_one() {
        Zipf::new(10, 1.0);
    }
}
