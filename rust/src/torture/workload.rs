//! Workload building blocks: the operation mix `m` and the adversarial
//! key generator used by the attack-mitigation experiments.

use crate::util::SplitMix64;

/// One hash-table operation kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Lookup,
    Insert,
    Delete,
    /// Last-wins overwrite-or-insert ([`crate::map::ConcurrentMap::upsert`]):
    /// the serving-shaped write the coordinator's `Put` issues. Population-
    /// neutral for keys already present, so it composes with the paper's
    /// stationary insert==delete protocol.
    Upsert,
}

/// The paper's operation mix `m`: a lookup percentage, with the remainder
/// split evenly between inserts and deletes (keeping the population
/// stationary, §6.1). Optionally a slice of the lookup share can be
/// re-dedicated to upserts ([`OpMix::with_upserts`]) to model overwrite-
/// heavy serving traffic; inserts still equal deletes, so the population
/// stays stationary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpMix {
    /// Lookup share in percent (0..=100).
    pub lookup: u8,
    /// Upsert share in percent, carved out of the lookup share
    /// (`upsert <= lookup`; 0 = the paper's original mix).
    pub upsert: u8,
}

impl OpMix {
    pub fn lookup_pct(lookup: u8) -> Self {
        assert!(lookup <= 100);
        Self { lookup, upsert: 0 }
    }

    /// The paper's mix with `upsert` points of the lookup share issued as
    /// last-wins upserts instead (read-mostly serving traffic with
    /// overwrites).
    pub fn with_upserts(lookup: u8, upsert: u8) -> Self {
        assert!(lookup <= 100 && upsert <= lookup);
        Self { lookup, upsert }
    }

    /// Sample an operation.
    #[inline(always)]
    pub fn pick(&self, rng: &mut SplitMix64) -> Op {
        let r = rng.next_bounded(100) as u8;
        if r < self.lookup {
            if r < self.upsert {
                Op::Upsert
            } else {
                Op::Lookup
            }
        } else if (r - self.lookup) % 2 == 0 {
            Op::Insert
        } else {
            Op::Delete
        }
    }
}

/// Generates keys that all collide under `key % nbuckets` — the
/// algorithmic-complexity attack (Crosby & Wallach) that motivates
/// dynamic hash tables (§1).
#[derive(Clone, Debug)]
pub struct AttackGen {
    nbuckets: u64,
    residue: u64,
    i: u64,
}

impl AttackGen {
    /// Attack keys congruent to `residue` modulo `nbuckets`.
    pub fn new(nbuckets: usize, residue: u64) -> Self {
        let nbuckets = nbuckets as u64;
        Self {
            nbuckets,
            residue: residue % nbuckets,
            i: 0,
        }
    }
}

impl Iterator for AttackGen {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        let k = self.residue + self.i * self.nbuckets;
        self.i += 1;
        Some(k)
    }
}

/// [`AttackGen`] restricted to keys that route to one shard of a
/// [`crate::dhash::ShardedDHash`]: every yielded key collides under
/// `key % nbuckets` *and* lands in the victim shard, leaving every other
/// shard's sample clean — the targeted-mitigation experiments.
#[derive(Clone, Debug)]
pub struct ShardedAttackGen {
    inner: AttackGen,
    nshards: usize,
    shard: usize,
}

impl ShardedAttackGen {
    /// Attack keys ≡ `residue` (mod `nbuckets`) routed to `shard` of
    /// `nshards` (a power of two, as the shard selector requires).
    pub fn new(nbuckets: usize, residue: u64, nshards: usize, shard: usize) -> Self {
        assert!(nshards.is_power_of_two(), "nshards must be a power of two");
        assert!(shard < nshards);
        Self {
            inner: AttackGen::new(nbuckets, residue),
            nshards,
            shard,
        }
    }
}

impl Iterator for ShardedAttackGen {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        // The inner generator is infinite and the mix64 selector spreads
        // its keys ~uniformly, so ~1/nshards of candidates match.
        self.inner
            .by_ref()
            .find(|&k| crate::dhash::shard_of(k, self.nshards) == self.shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_ops(mix: OpMix, seed: u64) -> [u32; 4] {
        let mut rng = SplitMix64::new(seed);
        let mut counts = [0u32; 4];
        for _ in 0..100_000 {
            match mix.pick(&mut rng) {
                Op::Lookup => counts[0] += 1,
                Op::Insert => counts[1] += 1,
                Op::Delete => counts[2] += 1,
                Op::Upsert => counts[3] += 1,
            }
        }
        counts
    }

    #[test]
    fn mix_respects_ratios() {
        let counts = count_ops(OpMix::lookup_pct(90), 1);
        let l = counts[0] as f64 / 1e5;
        assert!((l - 0.90).abs() < 0.01, "lookup share {l}");
        // insert ~= delete; the plain mix never upserts.
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((0.8..1.25).contains(&ratio), "ins/del ratio {ratio}");
        assert_eq!(counts[3], 0, "lookup_pct mix must not upsert");
    }

    #[test]
    fn mix_with_upserts_carves_the_lookup_share() {
        let counts = count_ops(OpMix::with_upserts(90, 20), 3);
        let l = counts[0] as f64 / 1e5;
        let u = counts[3] as f64 / 1e5;
        assert!((u - 0.20).abs() < 0.01, "upsert share {u}");
        assert!((l - 0.70).abs() < 0.01, "lookup share {l}");
        // The update halves are untouched: insert ~= delete ~= 5%.
        let i = counts[1] as f64 / 1e5;
        assert!((i - 0.05).abs() < 0.01, "insert share {i}");
    }

    #[test]
    fn mix_extremes() {
        let mut rng = SplitMix64::new(2);
        let all_lookup = OpMix::lookup_pct(100);
        assert!((0..1000).all(|_| all_lookup.pick(&mut rng) == Op::Lookup));
        let no_lookup = OpMix::lookup_pct(0);
        assert!((0..1000).all(|_| no_lookup.pick(&mut rng) != Op::Lookup));
    }

    #[test]
    fn sharded_attack_keys_collide_and_stay_in_shard() {
        let n = 1024;
        let (nshards, victim) = (4usize, 2usize);
        let keys: Vec<u64> = ShardedAttackGen::new(n, 3, nshards, victim).take(200).collect();
        assert_eq!(keys.len(), 200);
        assert!(keys.iter().all(|k| k % n as u64 == 3));
        assert!(keys
            .iter()
            .all(|&k| crate::dhash::shard_of(k, nshards) == victim));
        let set: std::collections::HashSet<_> = keys.iter().collect();
        assert_eq!(set.len(), 200);
    }

    #[test]
    fn attack_keys_collide_under_modulo() {
        let n = 64;
        let keys: Vec<u64> = AttackGen::new(n, 5).take(100).collect();
        assert_eq!(keys.len(), 100);
        assert!(keys.iter().all(|k| k % n as u64 == 5));
        // Distinct keys.
        let set: std::collections::HashSet<_> = keys.iter().collect();
        assert_eq!(set.len(), 100);
    }
}
