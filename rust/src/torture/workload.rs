//! Workload building blocks: the operation mix `m`, the adversarial
//! key generators used by the attack-mitigation experiments, and the
//! **elastic torture mode** — concurrent workers under a zipf-skewed
//! toggle mix while a resizer thread splits and merges shards online,
//! with directory-coherence invariants checked at every epoch.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::dhash::{HashFn, ShardedDHash};
use crate::lflist::BucketSet;
use crate::rcu::RcuThread;
use crate::util::SplitMix64;

/// One hash-table operation kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Lookup,
    Insert,
    Delete,
    /// Last-wins overwrite-or-insert ([`crate::map::ConcurrentMap::upsert`]):
    /// the serving-shaped write the coordinator's `Put` issues. Population-
    /// neutral for keys already present, so it composes with the paper's
    /// stationary insert==delete protocol.
    Upsert,
}

/// The paper's operation mix `m`: a lookup percentage, with the remainder
/// split evenly between inserts and deletes (keeping the population
/// stationary, §6.1). Optionally a slice of the lookup share can be
/// re-dedicated to upserts ([`OpMix::with_upserts`]) to model overwrite-
/// heavy serving traffic; inserts still equal deletes, so the population
/// stays stationary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpMix {
    /// Lookup share in percent (0..=100).
    pub lookup: u8,
    /// Upsert share in percent, carved out of the lookup share
    /// (`upsert <= lookup`; 0 = the paper's original mix).
    pub upsert: u8,
}

impl OpMix {
    pub fn lookup_pct(lookup: u8) -> Self {
        assert!(lookup <= 100);
        Self { lookup, upsert: 0 }
    }

    /// The paper's mix with `upsert` points of the lookup share issued as
    /// last-wins upserts instead (read-mostly serving traffic with
    /// overwrites).
    pub fn with_upserts(lookup: u8, upsert: u8) -> Self {
        assert!(lookup <= 100 && upsert <= lookup);
        Self { lookup, upsert }
    }

    /// Sample an operation.
    #[inline(always)]
    pub fn pick(&self, rng: &mut SplitMix64) -> Op {
        let r = rng.next_bounded(100) as u8;
        if r < self.lookup {
            if r < self.upsert {
                Op::Upsert
            } else {
                Op::Lookup
            }
        } else if (r - self.lookup) % 2 == 0 {
            Op::Insert
        } else {
            Op::Delete
        }
    }
}

/// Generates keys that all collide under `key % nbuckets` — the
/// algorithmic-complexity attack (Crosby & Wallach) that motivates
/// dynamic hash tables (§1).
#[derive(Clone, Debug)]
pub struct AttackGen {
    nbuckets: u64,
    residue: u64,
    i: u64,
}

impl AttackGen {
    /// Attack keys congruent to `residue` modulo `nbuckets`.
    pub fn new(nbuckets: usize, residue: u64) -> Self {
        let nbuckets = nbuckets as u64;
        Self {
            nbuckets,
            residue: residue % nbuckets,
            i: 0,
        }
    }
}

impl Iterator for AttackGen {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        let k = self.residue + self.i * self.nbuckets;
        self.i += 1;
        Some(k)
    }
}

/// [`AttackGen`] restricted to keys that route to one shard of a
/// [`crate::dhash::ShardedDHash`]: every yielded key collides under
/// `key % nbuckets` *and* lands in the victim shard, leaving every other
/// shard's sample clean — the targeted-mitigation experiments.
#[derive(Clone, Debug)]
pub struct ShardedAttackGen {
    inner: AttackGen,
    nshards: usize,
    shard: usize,
}

impl ShardedAttackGen {
    /// Attack keys ≡ `residue` (mod `nbuckets`) routed to `shard` of
    /// `nshards` (a power of two, as the shard selector requires).
    pub fn new(nbuckets: usize, residue: u64, nshards: usize, shard: usize) -> Self {
        assert!(nshards.is_power_of_two(), "nshards must be a power of two");
        assert!(shard < nshards);
        Self {
            inner: AttackGen::new(nbuckets, residue),
            nshards,
            shard,
        }
    }
}

impl Iterator for ShardedAttackGen {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        // The inner generator is infinite and the mix64 selector spreads
        // its keys ~uniformly, so ~1/nshards of candidates match.
        self.inner
            .by_ref()
            .find(|&k| crate::dhash::shard_of(k, self.nshards) == self.shard)
    }
}

/// Configuration for [`run_elastic`]: the elastic torture mode.
#[derive(Clone, Debug)]
pub struct ElasticTortureConfig {
    /// Toggle-worker thread count.
    pub threads: usize,
    /// Measurement window.
    pub duration: Duration,
    /// How long the resizer idles between split/merge bursts.
    pub resize_every: Duration,
    /// Keys each worker toggles (disjoint per worker, zipf-skewed).
    pub keys_per_thread: u64,
    /// Always-present keys inserted up front and never deleted: every
    /// worker asserts they resolve on every probe — the "Missing is
    /// never observed for a present key mid-split" invariant, under
    /// real concurrency.
    pub pinned: u64,
    /// Zipf exponent for the toggle-index skew (hot keys churn most).
    pub zipf_theta: f64,
    /// Target shard count the resizer grows to before merging back.
    pub grow_to: usize,
    pub seed: u64,
}

impl Default for ElasticTortureConfig {
    fn default() -> Self {
        Self {
            threads: 3,
            duration: Duration::from_millis(400),
            resize_every: Duration::from_millis(5),
            keys_per_thread: 256,
            pinned: 256,
            zipf_theta: 1.2,
            grow_to: 8,
            seed: 0xe1a5_71c5,
        }
    }
}

impl ElasticTortureConfig {
    /// Clamp for the CI smoke gate (no-op unless `DHASH_SMOKE=1`, like
    /// [`super::TortureConfig::clamped_for_smoke`]).
    pub fn clamped_for_smoke(mut self) -> Self {
        if super::smoke_mode() {
            self.threads = self.threads.min(2);
            self.duration = self.duration.min(Duration::from_millis(60));
            self.grow_to = self.grow_to.min(4);
        }
        self
    }
}

/// Result of one elastic torture run.
#[derive(Clone, Debug)]
pub struct ElasticReport {
    /// Completed worker operations.
    pub total_ops: u64,
    /// Splits / merges the resizer completed.
    pub splits: u64,
    pub merges: u64,
    /// Shard count and directory epoch at the end of the run.
    pub final_shards: usize,
    pub final_epoch: u64,
}

/// Run the elastic torture: `threads` workers toggle disjoint zipf-hot
/// key ranges (insert-if-absent / delete-if-present, asserting every
/// outcome) and probe the pinned always-present set, while the calling
/// thread splits shards up to `grow_to` and merges them back down,
/// checking after every resize that the directory-merged diagnostics
/// stay coherent: `snapshot` holds every pinned key, `bucket_loads`
/// matches the live geometry and never undercounts the pinned
/// population, and the migration gauge never exceeds one.
///
/// Returns the report; panics (failing the caller's test) on any
/// invariant violation. The final state is audited exactly: the map
/// holds precisely the pinned keys plus what the workers believe they
/// left behind.
pub fn run_elastic<B: BucketSet>(
    map: Arc<ShardedDHash<B>>,
    cfg: &ElasticTortureConfig,
) -> ElasticReport {
    const PIN_BASE: u64 = 1 << 50;
    const PIN_XOR: u64 = 0xF00D;
    {
        let g = RcuThread::register();
        for i in 0..cfg.pinned {
            map.insert(&g, PIN_BASE + i, (PIN_BASE + i) ^ PIN_XOR).unwrap();
        }
        g.quiescent_state();
    }

    let stop = Arc::new(AtomicBool::new(false));
    let ops = Arc::new(AtomicU64::new(0));
    let mut workers = Vec::new();
    for t in 0..cfg.threads {
        let map = map.clone();
        let stop = stop.clone();
        let ops = ops.clone();
        let cfg = cfg.clone();
        workers.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let zipf = super::Zipf::new(cfg.keys_per_thread, cfg.zipf_theta);
            let mut rng = SplitMix64::new(cfg.seed.wrapping_add(t as u64 * 0x9e37));
            let base = (t as u64 + 1) << 40; // disjoint from PIN_BASE
            let mut present = vec![false; cfg.keys_per_thread as usize];
            let mut local = 0u64;
            while !stop.load(Ordering::Relaxed) {
                for _ in 0..32 {
                    // Zipf-hot toggle on the worker's own range: single
                    // ownership per key makes every outcome exact.
                    let i = (zipf.sample(&mut rng) - 1) as usize;
                    let k = base + i as u64;
                    if present[i] {
                        assert!(
                            map.lookup(&g, k).is_some(),
                            "own present key {k} missed mid-resize"
                        );
                        assert!(map.delete(&g, k), "delete of present {k} failed");
                        present[i] = false;
                        assert!(map.lookup(&g, k).is_none(), "deleted key {k} resurrected");
                    } else {
                        assert!(map.insert(&g, k, k).is_ok(), "insert of absent {k} failed");
                        present[i] = true;
                    }
                    // Pinned probe: an always-present key must resolve,
                    // with its exact value, at every epoch.
                    if cfg.pinned > 0 {
                        let p = PIN_BASE + rng.next_bounded(cfg.pinned);
                        assert_eq!(
                            map.lookup(&g, p),
                            Some(p ^ PIN_XOR),
                            "pinned key {p} went missing mid-resize"
                        );
                    }
                    local += 2;
                }
                g.quiescent_state();
            }
            g.offline();
            ops.fetch_add(local, Ordering::Relaxed);
            present.iter().filter(|&&p| p).count()
        }));
    }

    // Adversarial stream: colliding keys (all ≡ 7 mod 64) aimed at one
    // selector region, churned net-zero (insert → probe → delete), so a
    // split/merge always migrates under same-bucket pressure. The
    // selector is a fixed bit-extension, so the flood keeps landing in
    // the attacked region's descendants as it splits.
    {
        let map = map.clone();
        let stop = stop.clone();
        let ops = ops.clone();
        let nshards0 = map.shards().max(2);
        workers.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut gen = ShardedAttackGen::new(64, 7, nshards0, 0);
            let mut local = 0u64;
            while !stop.load(Ordering::Relaxed) {
                for _ in 0..16 {
                    let k = gen.next().unwrap();
                    assert!(map.insert(&g, k, k).is_ok(), "attack key {k} collided");
                    assert_eq!(map.lookup(&g, k), Some(k), "attack key {k} missed");
                    assert!(map.delete(&g, k), "attack key {k} undeletable");
                    local += 3;
                }
                g.quiescent_state();
            }
            g.offline();
            ops.fetch_add(local, Ordering::Relaxed);
            0usize // net-zero churn leaves nothing behind
        }));
    }

    // The calling thread is the resizer: grow to `grow_to` shards, then
    // merge back down, checking invariants at every step.
    let g = RcuThread::register();
    let (mut splits, mut merges) = (0u64, 0u64);
    let t0 = Instant::now();
    let mut rng = SplitMix64::new(cfg.seed ^ 0x5eed);
    let mut growing = true;
    // Run for the window, but never report before at least one split
    // AND one merge completed (each loop turn performs exactly one
    // resize, so this tail is bounded by one grow/shrink cycle).
    while t0.elapsed() < cfg.duration || splits == 0 || merges == 0 {
        g.offline_while(|| std::thread::sleep(cfg.resize_every));
        assert!(map.migrating_shards() <= 1, "two migrations in flight");
        if growing {
            let s = (rng.next_bounded(map.shards() as u64)) as usize;
            match map.split_shard(&g, s, 32, HashFn::Seeded(rng.next_u64())) {
                Ok(_) => splits += 1,
                Err(e) => panic!("split of shard {s} failed: {e:?}"),
            }
            if map.shards() >= cfg.grow_to {
                growing = false;
            }
        } else {
            let s = (0..map.shards())
                .find(|&s| map.buddy_of(&g, s).is_some())
                .expect("a mergeable pair exists above one shard");
            match map.merge_shard(&g, s, 64, HashFn::Seeded(rng.next_u64())) {
                Ok(_) => merges += 1,
                Err(e) => panic!("merge of shard {s} failed: {e:?}"),
            }
            if map.shards() <= 2 {
                growing = true;
            }
        }
        // Directory-coherence invariants, checked under concurrency:
        // these scans merge sources, the hazard node, and destinations
        // across the current epoch, so the pinned population can never
        // transiently vanish from them.
        let snap_pairs = map.snapshot(&g);
        let mut missing = 0u64;
        for i in 0..cfg.pinned {
            let k = PIN_BASE + i;
            // Binary search: snapshot is key-sorted.
            if snap_pairs.binary_search_by_key(&k, |&(k, _)| k).is_err() {
                missing += 1;
            }
        }
        assert_eq!(missing, 0, "snapshot lost pinned keys at epoch {}", map.epoch());
        let loads = map.bucket_loads(&g);
        assert_eq!(
            loads.len(),
            map.nbuckets(&g),
            "bucket_loads shape diverged from the live geometry"
        );
        assert!(
            loads.iter().sum::<usize>() as u64 >= cfg.pinned,
            "bucket_loads undercounts the pinned population"
        );
        g.quiescent_state();
    }
    stop.store(true, Ordering::Relaxed);
    let leftover: usize = workers
        .into_iter()
        .map(|h| g.offline_while(|| h.join()).unwrap())
        .sum();

    // Exact final audit: pinned + whatever the workers left toggled on.
    assert_eq!(
        map.len(&g),
        cfg.pinned as usize + leftover,
        "final population diverged from the workers' view"
    );
    for i in 0..cfg.pinned {
        let k = PIN_BASE + i;
        assert_eq!(map.lookup(&g, k), Some(k ^ PIN_XOR), "pinned key {k} lost");
    }
    let report = ElasticReport {
        total_ops: ops.load(Ordering::Relaxed),
        splits,
        merges,
        final_shards: map.shards(),
        final_epoch: map.epoch(),
    };
    g.quiescent_state();
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_ops(mix: OpMix, seed: u64) -> [u32; 4] {
        let mut rng = SplitMix64::new(seed);
        let mut counts = [0u32; 4];
        for _ in 0..100_000 {
            match mix.pick(&mut rng) {
                Op::Lookup => counts[0] += 1,
                Op::Insert => counts[1] += 1,
                Op::Delete => counts[2] += 1,
                Op::Upsert => counts[3] += 1,
            }
        }
        counts
    }

    #[test]
    fn mix_respects_ratios() {
        let counts = count_ops(OpMix::lookup_pct(90), 1);
        let l = counts[0] as f64 / 1e5;
        assert!((l - 0.90).abs() < 0.01, "lookup share {l}");
        // insert ~= delete; the plain mix never upserts.
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((0.8..1.25).contains(&ratio), "ins/del ratio {ratio}");
        assert_eq!(counts[3], 0, "lookup_pct mix must not upsert");
    }

    #[test]
    fn mix_with_upserts_carves_the_lookup_share() {
        let counts = count_ops(OpMix::with_upserts(90, 20), 3);
        let l = counts[0] as f64 / 1e5;
        let u = counts[3] as f64 / 1e5;
        assert!((u - 0.20).abs() < 0.01, "upsert share {u}");
        assert!((l - 0.70).abs() < 0.01, "lookup share {l}");
        // The update halves are untouched: insert ~= delete ~= 5%.
        let i = counts[1] as f64 / 1e5;
        assert!((i - 0.05).abs() < 0.01, "insert share {i}");
    }

    #[test]
    fn mix_extremes() {
        let mut rng = SplitMix64::new(2);
        let all_lookup = OpMix::lookup_pct(100);
        assert!((0..1000).all(|_| all_lookup.pick(&mut rng) == Op::Lookup));
        let no_lookup = OpMix::lookup_pct(0);
        assert!((0..1000).all(|_| no_lookup.pick(&mut rng) != Op::Lookup));
    }

    #[test]
    fn sharded_attack_keys_collide_and_stay_in_shard() {
        let n = 1024;
        let (nshards, victim) = (4usize, 2usize);
        let keys: Vec<u64> = ShardedAttackGen::new(n, 3, nshards, victim).take(200).collect();
        assert_eq!(keys.len(), 200);
        assert!(keys.iter().all(|k| k % n as u64 == 3));
        assert!(keys
            .iter()
            .all(|&k| crate::dhash::shard_of(k, nshards) == victim));
        let set: std::collections::HashSet<_> = keys.iter().collect();
        assert_eq!(set.len(), 200);
    }

    #[test]
    fn attack_keys_collide_under_modulo() {
        let n = 64;
        let keys: Vec<u64> = AttackGen::new(n, 5).take(100).collect();
        assert_eq!(keys.len(), 100);
        assert!(keys.iter().all(|k| k % n as u64 == 5));
        // Distinct keys.
        let set: std::collections::HashSet<_> = keys.iter().collect();
        assert_eq!(set.len(), 100);
    }
}
