//! Shared bench-harness plumbing: quick/full sweeps, table construction,
//! and row printing. Used by every `[[bench]]` target.
#![allow(dead_code)] // shared across several bench targets; each uses a subset

use std::sync::Arc;
use std::time::Duration;

use dhash::baselines::{ConcurrentMap, HtRht, HtSplit, HtXu};
use dhash::dhash::{DHashMap, HashFn, ShardedDHash};
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};
use dhash::util::Summary;

/// Full paper-scale sweeps when `DHASH_BENCH_FULL=1`; CI-speed otherwise.
pub fn full_mode() -> bool {
    std::env::var("DHASH_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--full")
}

/// CI bench-smoke gate (`DHASH_SMOKE=1`): shrink every sweep so the whole
/// `cargo bench` suite is a compile-and-run check in well under 2 minutes.
/// No performance meaning; takes precedence over `full_mode`.
pub fn smoke_mode() -> bool {
    torture::smoke_mode()
}

pub fn measure_window() -> Duration {
    if smoke_mode() {
        Duration::from_millis(60)
    } else if full_mode() {
        Duration::from_millis(2000)
    } else {
        Duration::from_millis(300)
    }
}

pub fn repeats() -> usize {
    if smoke_mode() {
        1
    } else if full_mode() {
        5
    } else {
        2
    }
}

/// Worker-thread sweep (paper x-axis: up to 2x oversubscription of a
/// 24-core Ivy Bridge; this host is documented in the Table-1 header).
pub fn thread_sweep() -> Vec<usize> {
    if smoke_mode() {
        vec![1, 2]
    } else if full_mode() {
        vec![1, 2, 4, 8, 16, 24, 32, 48]
    } else {
        vec![1, 2, 4]
    }
}

pub const TABLES: [&str; 4] = ["dhash", "xu", "rht", "split"];

pub fn make_table(name: &str, nbuckets: usize, hash_seed: u64) -> Arc<dyn ConcurrentMap> {
    match name {
        "dhash" => Arc::new(DHashMap::with_buckets(nbuckets, hash_seed)),
        "xu" => Arc::new(HtXu::new(nbuckets, HashFn::Seeded(hash_seed))),
        "rht" => Arc::new(HtRht::new(nbuckets, HashFn::Seeded(hash_seed))),
        "split" => Arc::new(HtSplit::new(nbuckets, 1 << 20)),
        _ => unreachable!("unknown table {name}"),
    }
}

/// A `ShardedDHash` holding the same *total* bucket budget as an
/// unsharded table with `nbuckets_total` buckets.
pub fn make_sharded(
    shards: usize,
    nbuckets_total: usize,
    hash_seed: u64,
) -> Arc<dyn ConcurrentMap> {
    Arc::new(ShardedDHash::with_buckets(
        shards,
        (nbuckets_total / shards).max(1),
        hash_seed,
    ))
}

/// Machine-readable smoke-bench artifact. Under `DHASH_SMOKE=1` (the CI
/// gate) `flush` writes `BENCH_<name>.json` next to the bench's working
/// directory so the workflow can archive the perf trajectory PR over PR;
/// interactive and full runs keep stdout as the only interface.
pub struct BenchJson {
    name: &'static str,
    rows: Vec<String>,
}

impl BenchJson {
    pub fn new(name: &'static str) -> Self {
        Self {
            name,
            rows: Vec::new(),
        }
    }

    /// Record one row: a metric label plus numeric fields.
    pub fn row(&mut self, metric: &str, fields: &[(&str, f64)]) {
        let mut s = format!("{{\"metric\":\"{metric}\"");
        for (k, v) in fields {
            // Keep the file valid JSON even if a timer misbehaves.
            let v = if v.is_finite() { *v } else { -1.0 };
            s.push_str(&format!(",\"{k}\":{v}"));
        }
        s.push('}');
        self.rows.push(s);
    }

    /// Write `BENCH_<name>.json` when running as the CI smoke gate.
    pub fn flush(&self) {
        if !smoke_mode() {
            return;
        }
        let path = format!("BENCH_{}.json", self.name);
        let body = format!(
            "{{\"bench\":\"{}\",\"rows\":[{}]}}\n",
            self.name,
            self.rows.join(",")
        );
        match std::fs::write(&path, body) {
            Ok(()) => println!("# wrote {path} ({} rows)", self.rows.len()),
            Err(e) => eprintln!("BENCH json write failed ({path}): {e}"),
        }
    }
}

/// Shared single-op latency recorder over the crate's fixed-bucket
/// log-linear histogram ([`dhash::util::LatencyHistogram`]): nanosecond
/// samples in, `p50/p99/p999` out, with O(1) recording and no
/// allocations on the measurement path. Per-thread recorders merge into
/// one before reporting.
pub struct LatencyRecorder {
    hist: dhash::util::LatencyHistogram,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self {
            hist: dhash::util::LatencyHistogram::new(),
        }
    }

    /// Record one operation's wall time.
    pub fn record(&mut self, elapsed: Duration) {
        // u64 nanoseconds saturate past ~584 years; fine for op latency.
        self.hist.record(elapsed.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    /// Fold another thread's recorder into this one.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.hist.merge(&other.hist);
    }

    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    /// Print one human-readable percentile row and append the same
    /// numbers (nanoseconds) to `json` under `metric`.
    pub fn report(&self, json: &mut BenchJson, metric: &str) {
        let (p50, p99, p999) = (
            self.hist.percentile(0.50),
            self.hist.percentile(0.99),
            self.hist.percentile(0.999),
        );
        println!(
            "latency {metric:<16} n={:<9} p50_ns={p50:<8} p99_ns={p99:<8} \
             p999_ns={p999:<8} mean_ns={:<10.1} max_ns={}",
            self.hist.count(),
            self.hist.mean(),
            self.hist.max(),
        );
        json.row(
            metric,
            &[
                ("count", self.hist.count() as f64),
                ("p50_ns", p50 as f64),
                ("p99_ns", p99 as f64),
                ("p999_ns", p999 as f64),
                ("mean_ns", self.hist.mean()),
                ("max_ns", self.hist.max() as f64),
            ],
        );
    }
}

/// One Figure-2-style cell: throughput of `table` under the §6.2
/// continuous-rebuild protocol.
pub fn fig2_cell(table: &str, threads: usize, lookup_pct: u8, alpha: usize) -> Summary {
    let nbuckets = 1024;
    let cfg = TortureConfig {
        threads,
        mix: OpMix::lookup_pct(lookup_pct),
        alpha,
        nbuckets,
        // 0 = auto U = 2·α·β: keeps the population stationary at α·β so
        // the load factor stays what the panel says (see torture docs).
        key_range: 0,
        duration: measure_window(),
        rebuild: RebuildMode::Continuous { alt_nbuckets: nbuckets * 2 },
        pin: true,
        seed: 0xd1e5_5eed,
        hash_seed: 0x5eed,
    }
    .clamped_for_smoke();
    let map = make_table(table, cfg.nbuckets, cfg.hash_seed);
    let samples = torture::measure_mops(map, &cfg, repeats());
    Summary::of(&samples)
}

/// Print one figure row in a stable machine-parseable format.
pub fn row(fig: &str, table: &str, x: impl std::fmt::Display, s: &Summary) {
    println!(
        "{fig} table={table:<8} x={x:<6} mops_mean={:<8.3} mops_stddev={:.3}",
        s.mean, s.stddev
    );
}

/// Host characteristics, printed as the Table-1 substitute.
pub fn print_host_table1() {
    let cores = dhash::util::affinity::ncpus();
    println!("# Table 1 (this testbed; paper used Ivy Bridge / POWER9 / ARMv8):");
    println!("#   arch=x86_64 cores={cores} (container) rustc=release");
    println!("#   NOTE single-core host: thread sweeps measure oversubscription");
    println!("#   behaviour (lock contention vs lock-freedom), not parallel speedup.");
}
