//! Figure 3 regenerator: rebuilding efficiency — wall time of one rebuild
//! as a function of the number of nodes, with one concurrent worker
//! (paper §6.3: 90% lookups in fig3a, 80% in fig3b; y-axis log scale).
//!
//! Expected shape (paper observations, checked in EXPERIMENTS.md):
//!   * HT-Split lowest and flat (resize touches only the bucket array),
//!   * HT-Xu next (single traversal thanks to its two pointer sets),
//!   * DHash linear in n, clearly faster than HT-RHT,
//!   * HT-RHT slowest (tail distribution re-traverses chains).

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use common::{full_mode, make_table, print_host_table1, repeats};
use dhash::dhash::HashFn;
use dhash::rcu::{rcu_barrier, RcuThread};
use dhash::torture::OpMix;
use dhash::util::{SplitMix64, Summary};

/// Time one rebuild of `table` holding `nodes` keys while one worker
/// performs the `lookup_pct` mix (the paper's measurement protocol).
fn rebuild_time(table: &str, nodes: u64, lookup_pct: u8) -> f64 {
    // 128 buckets keeps chains long (the paper's high-load regime) even
    // at quick-mode node counts, so HT-RHT's per-node tail traversal
    // (quadratic per chain) is visible without the full 10^6-node sweep.
    let nbuckets = 128;
    let map = make_table(table, nbuckets, 1);
    {
        let g = RcuThread::register();
        for k in 0..nodes {
            map.insert(&g, k * 2, k); // even keys: worker uses odd too
        }
        g.quiescent_state();
    }
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let map = map.clone();
        let stop = stop.clone();
        let mix = OpMix::lookup_pct(lookup_pct);
        std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut rng = SplitMix64::new(7);
            while !stop.load(Ordering::Relaxed) {
                let k = rng.next_bounded(nodes * 2);
                match mix.pick(&mut rng) {
                    dhash::torture::workload::Op::Lookup => {
                        std::hint::black_box(map.lookup(&g, k));
                    }
                    dhash::torture::workload::Op::Insert => {
                        std::hint::black_box(map.insert(&g, k, k));
                    }
                    dhash::torture::workload::Op::Delete => {
                        std::hint::black_box(map.delete(&g, k));
                    }
                    dhash::torture::workload::Op::Upsert => {
                        std::hint::black_box(map.upsert(&g, k, k));
                    }
                }
                g.quiescent_state();
            }
            g.offline();
        })
    };
    let g = RcuThread::register();
    let t0 = Instant::now();
    assert!(map.rebuild(&g, nbuckets * 2, HashFn::Seeded(9)));
    let dt = t0.elapsed().as_secs_f64() * 1e3; // ms
    stop.store(true, Ordering::Relaxed);
    worker.join().unwrap();
    g.quiescent_state();
    rcu_barrier();
    dt
}

fn main() {
    print_host_table1();
    let node_counts: Vec<u64> = if common::smoke_mode() {
        vec![2_000, 8_000]
    } else if full_mode() {
        vec![10_000, 31_600, 100_000, 316_000, 1_000_000]
    } else {
        vec![5_000, 20_000, 80_000]
    };
    for (fig, lookup) in [("fig3a", 90u8), ("fig3b", 80u8)] {
        println!("# {fig}: rebuild time (ms) vs nodes, {lookup}% lookup worker");
        for table in common::TABLES {
            for &n in &node_counts {
                let samples: Vec<f64> =
                    (0..repeats()).map(|_| rebuild_time(table, n, lookup)).collect();
                let s = Summary::of(&samples);
                println!(
                    "{fig} table={table:<8} nodes={n:<8} ms_mean={:<10.3} ms_stddev={:.3}",
                    s.mean, s.stddev
                );
            }
        }
    }
}
