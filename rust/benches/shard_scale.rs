//! §Sharding scalability: `ShardedDHash` throughput under the §6.2
//! continuous-rebuild torture protocol, swept over shards ∈ {1, 4, 16} ×
//! worker threads at a constant total bucket budget. The trait-level
//! rebuild path drives the *staggered* `rebuild_all` (one shard migrating
//! at a time), so the sweep measures exactly what sharding buys: smaller
//! migration working sets and rebuild/update parallelism across shards.
//!
//! Under `DHASH_SMOKE=1` the rows are also written to
//! `BENCH_shard_scale.json` (see `common::BenchJson`).

mod common;

use std::sync::Arc;

use dhash::map::ConcurrentMap;
use dhash::rcu::rcu_barrier;
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};
use dhash::util::Summary;

const TOTAL_BUCKETS: usize = 1024;
const SHARD_SWEEP: [usize; 3] = [1, 4, 16];

fn main() {
    common::print_host_table1();
    let mut json = common::BenchJson::new("shard_scale");
    for &shards in &SHARD_SWEEP {
        for &threads in &common::thread_sweep() {
            let cfg = TortureConfig {
                threads,
                mix: OpMix::lookup_pct(90),
                alpha: 20,
                nbuckets: TOTAL_BUCKETS,
                key_range: 0, // auto: stationary 2·α·β
                duration: common::measure_window(),
                rebuild: RebuildMode::Continuous {
                    alt_nbuckets: TOTAL_BUCKETS * 2,
                },
                pin: true,
                seed: 0xd1e5_5eed,
                hash_seed: 0x5eed,
            }
            .clamped_for_smoke();
            let map: Arc<dyn ConcurrentMap> =
                common::make_sharded(shards, cfg.nbuckets, cfg.hash_seed);
            let samples = torture::measure_mops(map, &cfg, common::repeats());
            let s = Summary::of(&samples);
            println!(
                "shard_scale shards={shards:<3} threads={threads:<3} \
                 mops_mean={:<8.3} mops_stddev={:.3}",
                s.mean, s.stddev
            );
            json.row(
                "throughput",
                &[
                    ("shards", shards as f64),
                    ("threads", threads as f64),
                    ("mops_mean", s.mean),
                    ("mops_stddev", s.stddev),
                ],
            );
        }
    }
    json.flush();
    rcu_barrier();
}
