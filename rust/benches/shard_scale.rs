//! §Sharding scalability: `ShardedDHash` throughput under the §6.2
//! continuous-rebuild torture protocol, swept over shards ∈ {1, 4, 16} ×
//! worker threads at a constant total bucket budget. The trait-level
//! rebuild path drives the *staggered* `rebuild_all` (one shard migrating
//! at a time), so the sweep measures exactly what sharding buys: smaller
//! migration working sets and rebuild/update parallelism across shards.
//!
//! A second sweep drives the sharded *coordinator* over the pre-route
//! axis (off | shard | bucket): the locality win of sorting batches by
//! the full `(shard, bucket)` composite id from one `batch_hash_multi`
//! engine call, vs shard-id order, vs arrival order.
//!
//! A third sweep drives the **elastic axis**: the same Bucket-pre-routed
//! ingest with a shard split + merge landing mid-window vs a fixed
//! layout, measuring what an online resize costs the request path.
//!
//! Under `DHASH_SMOKE=1` the rows are also written to
//! `BENCH_shard_scale.json` / `BENCH_elastic.json` (see
//! `common::BenchJson`), and the smoke run asserts the sharded
//! bucket-order path reports zero engine/length fallbacks — on the
//! elastic axis too, where only the counted epoch fallback inside the
//! resize window is tolerated.

mod common;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dhash::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, CoordinatorStats, PreRoute, Request,
};
use dhash::dhash::HashFn;
use dhash::map::ConcurrentMap;
use dhash::rcu::rcu_barrier;
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};
use dhash::util::{SplitMix64, Summary};

const TOTAL_BUCKETS: usize = 1024;
const SHARD_SWEEP: [usize; 3] = [1, 4, 16];

/// Coordinator ingest throughput for one (shards, pre_route) cell, plus
/// the run's routing counters.
fn pre_route_cell(shards: usize, pre_route: PreRoute) -> (f64, CoordinatorStats) {
    let cfg = CoordinatorConfig {
        // >= detector nbins per shard, so analytics (which Bucket mode
        // needs for its engine) reads healthy chi2 on benign load.
        nbuckets: 1024,
        hash: HashFn::Seeded(0x5eed),
        shards,
        lanes: shards.min(4),
        workers: 2,
        batcher: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            pre_route,
        },
        enable_analytics: true,
        ..Default::default()
    };
    let c = Arc::new(Coordinator::start(cfg).expect("default engine"));
    let stop = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for t in 0..2u64 {
        let c2 = c.clone();
        let s2 = stop.clone();
        let d2 = done.clone();
        clients.push(std::thread::spawn(move || {
            let kv = c2.client();
            let mut rng = SplitMix64::new(t + 1);
            while !s2.load(Ordering::Relaxed) {
                let reqs: Vec<Request> = (0..64)
                    .map(|_| {
                        let k = rng.next_bounded(1_000_000);
                        if rng.next_f64() < 0.9 {
                            Request::get(k)
                        } else {
                            Request::put(k, k)
                        }
                    })
                    .collect();
                let n = reqs.len() as u64;
                match kv.submit_batch(&reqs) {
                    Ok(ticket) => {
                        let _ = ticket.wait();
                        d2.fetch_add(n, Ordering::Relaxed);
                    }
                    Err(_) => break,
                }
            }
        }));
    }
    let window = common::measure_window();
    std::thread::sleep(window);
    stop.store(true, Ordering::Relaxed);
    for cl in clients {
        cl.join().unwrap();
    }
    c.shutdown();
    let req_per_s = done.load(Ordering::Relaxed) as f64 / window.as_secs_f64();
    (req_per_s, c.stats())
}

fn bench_pre_route(json: &mut common::BenchJson) {
    println!("# shard_scale pre-route axis: coordinator ingest, off|shard|bucket");
    for &shards in &[1usize, 4] {
        for pre_route in [PreRoute::Off, PreRoute::Shard, PreRoute::Bucket] {
            let (req_per_s, st) = pre_route_cell(shards, pre_route);
            println!(
                "shard_scale shards={shards:<3} pre_route={:<6} req_per_s={req_per_s:<10.0} \
                 routed={} fb_len={} fb_eng={}",
                pre_route.label(),
                st.pre_routed_batches,
                st.pre_route_fallbacks_length,
                st.pre_route_fallbacks_engine
            );
            json.row(
                "ingest",
                &[
                    ("shards", shards as f64),
                    ("pre_route", pre_route.code() as f64),
                    ("req_per_s", req_per_s),
                    ("pre_routed_batches", st.pre_routed_batches as f64),
                    ("fallbacks_engine", st.pre_route_fallbacks_engine as f64),
                ],
            );
            if common::smoke_mode() && pre_route != PreRoute::Off {
                // The CI gate for the silent-degradation bug: on the
                // native engine, every sharded pre-route must succeed.
                assert_eq!(
                    st.pre_route_fallbacks_engine, 0,
                    "shards={shards} {}: engine fallbacks in smoke run",
                    pre_route.label()
                );
                assert_eq!(
                    st.pre_route_fallbacks_length, 0,
                    "shards={shards} {}: length fallbacks in smoke run",
                    pre_route.label()
                );
            }
        }
    }
}

/// One elastic-axis cell: coordinator ingest throughput with Bucket
/// pre-routing, either at a fixed shard count or with a split + merge
/// landing mid-window (what the elastic policy does under a load swing).
/// Returns req/s plus the run's routing + resize counters.
fn elastic_cell(resize_mid_run: bool) -> (f64, CoordinatorStats) {
    let cfg = CoordinatorConfig {
        nbuckets: 1024,
        hash: HashFn::Seeded(0x5eed),
        shards: 4,
        lanes: 2,
        workers: 2,
        batcher: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            pre_route: PreRoute::Bucket,
        },
        enable_analytics: true,
        ..Default::default()
    };
    let c = Arc::new(Coordinator::start(cfg).expect("default engine"));
    let stop = Arc::new(AtomicBool::new(false));
    let done = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for t in 0..2u64 {
        let c2 = c.clone();
        let s2 = stop.clone();
        let d2 = done.clone();
        clients.push(std::thread::spawn(move || {
            let kv = c2.client();
            let mut rng = SplitMix64::new(t + 1);
            while !s2.load(Ordering::Relaxed) {
                let reqs: Vec<Request> = (0..64)
                    .map(|_| {
                        let k = rng.next_bounded(1_000_000);
                        if rng.next_f64() < 0.9 {
                            Request::get(k)
                        } else {
                            Request::put(k, k)
                        }
                    })
                    .collect();
                let n = reqs.len() as u64;
                match kv.submit_batch(&reqs) {
                    Ok(ticket) => {
                        let _ = ticket.wait();
                        d2.fetch_add(n, Ordering::Relaxed);
                    }
                    Err(_) => break,
                }
            }
        }));
    }
    let window = common::measure_window();
    if resize_mid_run {
        // Resize in the middle of the measured window: one split, then
        // the inverse merge, exactly the swing the elastic policy makes.
        // Sleeps go OFFLINE — an online-but-idle registered thread would
        // stall every grace period (and all deferred reclamation) for
        // the rest of the window, skewing the resize cell.
        let g = dhash::rcu::RcuThread::register();
        g.offline_while(|| std::thread::sleep(window / 3));
        c.map()
            .split_shard(&g, 1, 1024, HashFn::Seeded(0xe1a5))
            .expect("bench split");
        g.offline_while(|| std::thread::sleep(window / 3));
        c.map()
            .merge_shard(&g, 1, 2048, HashFn::Seeded(0xe1a6))
            .expect("bench merge");
        g.quiescent_state();
        g.offline_while(|| std::thread::sleep(window / 3));
    } else {
        std::thread::sleep(window);
    }
    stop.store(true, Ordering::Relaxed);
    for cl in clients {
        cl.join().unwrap();
    }
    c.shutdown();
    let req_per_s = done.load(Ordering::Relaxed) as f64 / window.as_secs_f64();
    (req_per_s, c.stats())
}

fn bench_elastic() {
    println!("# elastic axis: Bucket-pre-routed ingest, fixed vs split+merge mid-run");
    let mut json = common::BenchJson::new("elastic");
    for resize in [false, true] {
        let (req_per_s, st) = elastic_cell(resize);
        println!(
            "elastic resize_mid_run={:<5} req_per_s={req_per_s:<10.0} routed={} fb_len={} \
             fb_eng={} fb_ep={} splits={} merges={} epoch={}",
            resize,
            st.pre_routed_batches,
            st.pre_route_fallbacks_length,
            st.pre_route_fallbacks_engine,
            st.pre_route_fallbacks_epoch,
            st.splits,
            st.merges,
            st.epoch
        );
        json.row(
            "ingest",
            &[
                ("elastic", resize as u64 as f64),
                ("req_per_s", req_per_s),
                ("pre_routed_batches", st.pre_routed_batches as f64),
                ("fallbacks_engine", st.pre_route_fallbacks_engine as f64),
                ("fallbacks_length", st.pre_route_fallbacks_length as f64),
                ("fallbacks_epoch", st.pre_route_fallbacks_epoch as f64),
                ("splits", st.splits as f64),
                ("merges", st.merges as f64),
            ],
        );
        if common::smoke_mode() {
            // The CI gate: on the native engine, a settled split must
            // leave routing fully healthy — the only tolerated fallback
            // cause is the (counted) epoch race inside the resize window.
            assert_eq!(
                st.pre_route_fallbacks_engine, 0,
                "elastic resize={resize}: engine fallbacks in smoke run"
            );
            assert_eq!(
                st.pre_route_fallbacks_length, 0,
                "elastic resize={resize}: length fallbacks in smoke run"
            );
            if resize {
                assert_eq!(st.splits, 1);
                assert_eq!(st.merges, 1);
            } else {
                assert_eq!(st.pre_route_fallbacks_epoch, 0, "epoch fallback without a resize");
            }
        }
    }
    json.flush();
}

fn main() {
    common::print_host_table1();
    let mut json = common::BenchJson::new("shard_scale");
    for &shards in &SHARD_SWEEP {
        for &threads in &common::thread_sweep() {
            let cfg = TortureConfig {
                threads,
                mix: OpMix::lookup_pct(90),
                alpha: 20,
                nbuckets: TOTAL_BUCKETS,
                key_range: 0, // auto: stationary 2·α·β
                duration: common::measure_window(),
                rebuild: RebuildMode::Continuous {
                    alt_nbuckets: TOTAL_BUCKETS * 2,
                },
                pin: true,
                seed: 0xd1e5_5eed,
                hash_seed: 0x5eed,
            }
            .clamped_for_smoke();
            let map: Arc<dyn ConcurrentMap> =
                common::make_sharded(shards, cfg.nbuckets, cfg.hash_seed);
            let samples = torture::measure_mops(map, &cfg, common::repeats());
            let s = Summary::of(&samples);
            println!(
                "shard_scale shards={shards:<3} threads={threads:<3} \
                 mops_mean={:<8.3} mops_stddev={:.3}",
                s.mean, s.stddev
            );
            json.row(
                "throughput",
                &[
                    ("shards", shards as f64),
                    ("threads", threads as f64),
                    ("mops_mean", s.mean),
                    ("mops_stddev", s.stddev),
                ],
            );
        }
    }
    bench_pre_route(&mut json);
    json.flush();
    bench_elastic();
    rcu_barrier();
}
