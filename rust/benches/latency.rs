//! Single-op read/write latency scoreboard: p50/p99/p999 over the
//! sharded map in three regimes — idle, a per-shard rebuild storm, and a
//! split/merge storm — plus the batcher-oracle snapshot-cache check.
//!
//! Throughput benches (fig2..4, shard_scale) average over a window and
//! hide tail pain; this one times every operation into the fixed-bucket
//! log-linear histogram (`util::stats::LatencyHistogram`, ≤1/32 relative
//! error, O(1) record) so the read-path orderings/padding work shows up
//! where it matters: the p99/p999 gap between idle and storm columns.
//!
//! Under `DHASH_SMOKE=1` the run writes `BENCH_latency.json` and asserts
//! the steady-path routing oracle serves every batch from its cached
//! `RouteSnapshot` (zero rebuilds while the directory epoch is
//! unchanged).

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use common::{measure_window, print_host_table1, BenchJson, LatencyRecorder};
use dhash::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, PreRoute, Request, Response,
};
use dhash::dhash::{HashFn, ShardedDHash};
use dhash::rcu::RcuThread;
use dhash::util::SplitMix64;

const SHARDS: usize = 4;
const NBUCKETS_PER_SHARD: usize = 256;
const KEYS: u64 = 4096;
const MEASURE_THREADS: usize = 2;

fn key_of(i: u64) -> u64 {
    i.wrapping_mul(0x9e37) // spread keys; stays well clear of u64::MAX
}

fn populate(map: &ShardedDHash) {
    let g = RcuThread::register();
    for i in 0..KEYS {
        map.insert(&g, key_of(i), i).unwrap();
    }
    g.quiescent_state();
}

/// Time single ops on `MEASURE_THREADS` threads for one measurement
/// window while `storm` churns the map from its own thread; returns the
/// merged (read, write) recorders.
fn run_scenario(
    map: &Arc<ShardedDHash>,
    storm: impl FnOnce(&AtomicBool, &ShardedDHash) + Send,
) -> (LatencyRecorder, LatencyRecorder) {
    let stop = AtomicBool::new(false);
    let window = measure_window();
    std::thread::scope(|s| {
        let mut measurers = Vec::new();
        for t in 0..MEASURE_THREADS {
            let map = map.clone();
            let stop = &stop;
            measurers.push(s.spawn(move || {
                let g = RcuThread::register();
                let mut rng = SplitMix64::new(0xbeef + t as u64);
                let mut reads = LatencyRecorder::new();
                let mut writes = LatencyRecorder::new();
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let k = key_of(rng.next_bounded(KEYS));
                    if i % 4 == 3 {
                        let t0 = Instant::now();
                        map.upsert(&g, k, i);
                        writes.record(t0.elapsed());
                    } else {
                        let t0 = Instant::now();
                        std::hint::black_box(map.lookup(&g, k));
                        reads.record(t0.elapsed());
                    }
                    // Quiesce every op: storm grace periods must never
                    // wait on a measurement thread.
                    g.quiescent_state();
                    i += 1;
                }
                (reads, writes)
            }));
        }
        let storm_h = s.spawn(|| storm(&stop, map.as_ref()));
        std::thread::sleep(window);
        stop.store(true, Ordering::Relaxed);
        let mut reads = LatencyRecorder::new();
        let mut writes = LatencyRecorder::new();
        for m in measurers {
            let (r, w) = m.join().unwrap();
            reads.merge(&r);
            writes.merge(&w);
        }
        storm_h.join().unwrap();
        (reads, writes)
    })
}

fn no_storm(stop: &AtomicBool, _map: &ShardedDHash) {
    while !stop.load(Ordering::Relaxed) {
        std::thread::yield_now();
    }
}

/// Continuous per-shard rebuilds (the §6.2 regime, sharded): every shard
/// re-seeded round-robin, one migration at a time through the token.
fn rebuild_storm(stop: &AtomicBool, map: &ShardedDHash) {
    let g = RcuThread::register();
    let mut i = 0u64;
    while !stop.load(Ordering::Relaxed) {
        for s in 0..map.shards() {
            let _ = map.rebuild_shard(&g, s, NBUCKETS_PER_SHARD, HashFn::Seeded(0x5eed ^ i));
            if stop.load(Ordering::Relaxed) {
                break;
            }
        }
        i += 1;
        g.quiescent_state();
    }
}

/// Continuous directory churn: split shard 0, merge it back, repeat —
/// every iteration bumps the epoch twice and drags keys through the
/// cross-shard `moving` hazard protocol.
fn split_merge_storm(stop: &AtomicBool, map: &ShardedDHash) {
    let g = RcuThread::register();
    while !stop.load(Ordering::Relaxed) {
        let _ = map.split_shard(&g, 0, NBUCKETS_PER_SHARD, HashFn::Seeded(0x51de));
        let _ = map.merge_shard(&g, 0, NBUCKETS_PER_SHARD, HashFn::Seeded(0x51de));
        g.quiescent_state();
    }
}

/// The steady path of the pre-route oracle must be allocation-free: one
/// `RouteSnapshot` build per lane at first use, then every batch served
/// from the epoch-keyed cache until a split/merge moves the epoch.
fn oracle_cache_check(json: &mut BenchJson) {
    let cfg = CoordinatorConfig {
        nbuckets: 512,
        hash: HashFn::Seeded(0xfeed),
        shards: SHARDS,
        lanes: 2,
        workers: 2,
        batcher: BatcherConfig {
            pre_route: PreRoute::Bucket,
            ..Default::default()
        },
        ..Default::default()
    };
    let lanes = cfg.lanes as u64;
    let c = Coordinator::start(cfg).expect("coordinator start");
    let client = c.client();
    let run_batches = |rounds: u64| {
        for r in 0..rounds {
            let reqs: Vec<Request> = (0..256u64)
                .map(|i| {
                    let k = key_of(r * 256 + i);
                    if i % 2 == 0 {
                        Request::put(k, i)
                    } else {
                        Request::get(k)
                    }
                })
                .collect();
            let resps = client.submit_batch(&reqs).unwrap().wait().unwrap();
            assert_eq!(resps.len(), 256);
            // Every put slot must have resolved Ok (gets may miss: odd
            // keys are probed, only even ones were written).
            assert!(resps
                .iter()
                .step_by(2)
                .all(|r| *r == Response::Ok));
        }
    };
    let epoch0 = c.map().epoch();
    run_batches(8); // warm both lanes: each builds its snapshot once
    let warm = c.stats();
    run_batches(24);
    let st = c.stats();
    c.shutdown();
    assert_eq!(
        c.map().epoch(),
        epoch0,
        "no split/merge ran; the epoch must not move"
    );
    assert!(
        warm.snapshot_rebuilds <= lanes,
        "cold start must build at most one snapshot per lane, got {}",
        warm.snapshot_rebuilds
    );
    assert_eq!(
        st.snapshot_rebuilds, warm.snapshot_rebuilds,
        "steady path (epoch unchanged) must perform ZERO snapshot rebuilds"
    );
    println!(
        "oracle_cache: batches={} snapshot_rebuilds={} (lanes={lanes}, epoch stable)",
        st.total_batches, st.snapshot_rebuilds
    );
    json.row(
        "oracle_cache",
        &[
            ("batches", st.total_batches as f64),
            ("snapshot_rebuilds", st.snapshot_rebuilds as f64),
            ("lanes", lanes as f64),
        ],
    );
}

fn main() {
    print_host_table1();
    println!("# Single-op latency (ns): {MEASURE_THREADS} measurement threads, 3:1 read:write");
    let mut json = BenchJson::new("latency");

    let scenarios: [(&str, fn(&AtomicBool, &ShardedDHash)); 3] = [
        ("idle", no_storm),
        ("rebuild", rebuild_storm),
        ("splitmerge", split_merge_storm),
    ];
    for (name, storm) in scenarios {
        let map = Arc::new(ShardedDHash::with_hash(
            SHARDS,
            NBUCKETS_PER_SHARD,
            HashFn::Seeded(0xd1e5),
        ));
        populate(&map);
        let (reads, writes) = run_scenario(&map, storm);
        assert!(reads.count() > 0 && writes.count() > 0, "{name}: no samples");
        reads.report(&mut json, &format!("{name}_read"));
        writes.report(&mut json, &format!("{name}_write"));
    }

    oracle_cache_check(&mut json);
    json.flush();
}
