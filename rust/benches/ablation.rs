//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   buckets   — DHash over MichaelList vs SpinlockList vs CowSortedArray
//!               (paper goal 2: the progress/performance trade-off).
//!   hazard    — lookups with vs without the `rebuild_cur` check: the
//!               no-check variant exhibits false negatives under rebuild
//!               (why Lemma 4.1's ordering exists) and the check costs
//!               nothing when no rebuild runs.
//!   distrib   — head-node distribution (DHash) vs tail-node (HT-RHT):
//!               rebuild node throughput (explains Figure 3).
//!   batchhash — coordinator batcher with/without AOT batch pre-hashing
//!               (skipped gracefully when artifacts are absent).

mod common;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{full_mode, make_table, measure_window, repeats};
use dhash::baselines::ConcurrentMap;
use dhash::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, PreRoute, Request};
use dhash::dhash::{DHashMap, HashFn};
use dhash::lflist::{CowSortedArray, MichaelList, SpinlockList};
use dhash::rcu::{rcu_barrier, RcuThread};
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};
use dhash::util::{SplitMix64, Summary};

fn bucket_cfg(threads: usize, alpha: usize) -> TortureConfig {
    TortureConfig {
        threads,
        mix: OpMix::lookup_pct(90),
        alpha,
        nbuckets: 512,
        key_range: 500_000,
        duration: measure_window(),
        rebuild: RebuildMode::Continuous { alt_nbuckets: 1024 },
        pin: true,
        seed: 11,
        hash_seed: 5,
    }
    .clamped_for_smoke()
}

fn bench_buckets() {
    println!("# ablation buckets: DHash bucket-set algorithms, 90% lookups");
    let threads = if full_mode() { vec![1, 4, 16] } else { vec![2] };
    let alphas = if full_mode() { vec![20usize, 200] } else { vec![20] };
    for alpha in alphas {
        for &t in &threads {
            let variants: Vec<(&str, Arc<dyn ConcurrentMap>)> = vec![
                ("michael", Arc::new(DHashMap::<MichaelList>::with_hash(512, HashFn::Seeded(5)))),
                ("spinlock", Arc::new(DHashMap::<SpinlockList>::with_hash(512, HashFn::Seeded(5)))),
                ("cow", Arc::new(DHashMap::<CowSortedArray>::with_hash(512, HashFn::Seeded(5)))),
            ];
            for (name, map) in variants {
                let cfg = bucket_cfg(t, alpha);
                let samples = torture::measure_mops(map, &cfg, repeats());
                let s = Summary::of(&samples);
                println!(
                    "buckets variant={name:<9} alpha={alpha:<4} threads={t:<3} \
                     mops_mean={:<8.3} mops_stddev={:.3}",
                    s.mean, s.stddev
                );
            }
        }
    }
}

fn bench_hazard() {
    println!("# ablation hazard: lookup false negatives without the rebuild_cur check");
    let map = Arc::new(DHashMap::<MichaelList>::with_hash(64, HashFn::Seeded(3)));
    let nkeys = 20_000u64;
    {
        let g = RcuThread::register();
        for k in 0..nkeys {
            map.insert(&g, k, k).unwrap();
        }
        g.quiescent_state();
    }
    for skip_check in [false, true] {
        let stop = Arc::new(AtomicBool::new(false));
        let misses = Arc::new(AtomicU64::new(0));
        let lookups = Arc::new(AtomicU64::new(0));
        let mut readers = Vec::new();
        for r in 0..2u64 {
            let map = map.clone();
            let stop = stop.clone();
            let misses = misses.clone();
            let lookups = lookups.clone();
            readers.push(std::thread::spawn(move || {
                let g = RcuThread::register();
                let mut rng = SplitMix64::new(r + 1);
                while !stop.load(Ordering::Relaxed) {
                    let k = rng.next_bounded(nkeys);
                    let hit = if skip_check {
                        map.lookup_skip_hazard_check(&g, k).is_some()
                    } else {
                        map.lookup(&g, k).is_some()
                    };
                    if !hit {
                        misses.fetch_add(1, Ordering::Relaxed);
                    }
                    lookups.fetch_add(1, Ordering::Relaxed);
                    g.quiescent_state();
                }
                g.offline();
            }));
        }
        {
            let g = RcuThread::register();
            let rounds = if common::smoke_mode() {
                2
            } else if full_mode() {
                12
            } else {
                4
            };
            for i in 0..rounds {
                map.rebuild(&g, if i % 2 == 0 { 128 } else { 64 }, HashFn::Seeded(50 + i))
                    .unwrap();
            }
            g.quiescent_state();
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        let m = misses.load(Ordering::Relaxed);
        let l = lookups.load(Ordering::Relaxed).max(1);
        println!(
            "hazard check={} lookups={l} false_negatives={m} rate={:.3e}",
            if skip_check { "OFF" } else { "ON " },
            m as f64 / l as f64
        );
    }
    rcu_barrier();
}

fn bench_distrib() {
    println!("# ablation distrib: rebuild node-throughput, head (DHash) vs tail (HT-RHT)");
    let nodes: u64 = if common::smoke_mode() {
        8_000
    } else if full_mode() {
        200_000
    } else {
        40_000
    };
    for table in ["dhash", "rht", "xu", "split"] {
        let samples: Vec<f64> = (0..repeats())
            .map(|_| {
                let map = make_table(table, 1024, 1);
                let g = RcuThread::register();
                for k in 0..nodes {
                    map.insert(&g, k, k);
                }
                let t0 = Instant::now();
                map.rebuild(&g, 2048, HashFn::Seeded(2));
                let dt = t0.elapsed().as_secs_f64();
                g.quiescent_state();
                rcu_barrier();
                nodes as f64 / dt / 1e6 // Mnodes/s
            })
            .collect();
        let s = Summary::of(&samples);
        println!(
            "distrib table={table:<8} nodes={nodes} mnodes_per_s_mean={:<8.3} stddev={:.3}",
            s.mean, s.stddev
        );
    }
}

fn bench_batchhash() {
    println!("# ablation batchhash: coordinator throughput, pre-route mode x ingest lanes");
    // Sharded rows separate the shard-order baseline from the full
    // (shard, bucket) composite order one batch_hash_multi call buys.
    for (lanes, shards, pre_route) in [
        (1, 1, PreRoute::Off),
        (1, 1, PreRoute::Bucket),
        (4, 4, PreRoute::Off),
        (4, 4, PreRoute::Shard),
        (4, 4, PreRoute::Bucket),
    ] {
        let cfg = CoordinatorConfig {
            nbuckets: 4096,
            hash: HashFn::Seeded(9),
            shards,
            lanes,
            workers: 2,
            batcher: BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(200),
                pre_route,
            },
            enable_analytics: true,
            ..Default::default()
        };
        let c = Arc::new(Coordinator::start(cfg).expect("default engine"));
        let stop = Arc::new(AtomicBool::new(false));
        let done = Arc::new(AtomicU64::new(0));
        let mut clients = Vec::new();
        for t in 0..2u64 {
            let c2 = c.clone();
            let s2 = stop.clone();
            let d2 = done.clone();
            clients.push(std::thread::spawn(move || {
                let mut rng = SplitMix64::new(t);
                while !s2.load(Ordering::Relaxed) {
                    let reqs: Vec<Request> = (0..64)
                        .map(|_| {
                            let k = rng.next_bounded(1_000_000);
                            if rng.next_f64() < 0.9 {
                                Request::get(k)
                            } else {
                                Request::put(k, k)
                            }
                        })
                        .collect();
                    let n = reqs.len() as u64;
                    c2.execute_many(reqs);
                    d2.fetch_add(n, Ordering::Relaxed);
                }
            }));
        }
        let window = if common::smoke_mode() {
            measure_window()
        } else {
            measure_window().max(Duration::from_millis(500))
        };
        std::thread::sleep(window);
        stop.store(true, Ordering::Relaxed);
        for cl in clients {
            cl.join().unwrap();
        }
        c.shutdown();
        let reqs = done.load(Ordering::Relaxed);
        let st = c.stats();
        println!(
            "batchhash pre_route={:<6} lanes={lanes} shards={shards} req_per_s={:.0} \
             routed={} fb_len={} fb_eng={}",
            pre_route.label(),
            reqs as f64 / window.as_secs_f64(),
            st.pre_routed_batches,
            st.pre_route_fallbacks_length,
            st.pre_route_fallbacks_engine
        );
        if common::smoke_mode() && pre_route != PreRoute::Off {
            // The native engine serves every pre-route: a fallback here
            // means the silent-degradation bug is back.
            assert_eq!(st.pre_route_fallbacks_engine, 0, "engine fallbacks in smoke run");
            assert_eq!(st.pre_route_fallbacks_length, 0, "length fallbacks in smoke run");
        }
    }
}

fn main() {
    common::print_host_table1();
    bench_buckets();
    bench_hazard();
    bench_distrib();
    bench_batchhash();
}
