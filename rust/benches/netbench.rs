//! §Network front end: wire-protocol serving throughput over loopback,
//! swept connections × pipeline depth. Each client pipelines `depth`
//! requests over its own TCP connection against a `NetServer` running
//! on an ephemeral port, so the sweep measures the full path: framing →
//! epoll workers → one `submit_batch` per drain → completion-driven
//! response writes.
//!
//! Under `DHASH_SMOKE=1` the rows are also written to `BENCH_net.json`
//! (see `common::BenchJson`), picked up by the CI `bench-smoke-json`
//! artifact glob.

mod common;

#[cfg(target_os = "linux")]
fn main() {
    use dhash::coordinator::{Coordinator, CoordinatorConfig};
    use dhash::net::{bench, BenchReport, NetConfig, NetServer};

    common::print_host_table1();
    let mut json = common::BenchJson::new("net");

    let conn_sweep: Vec<usize> = if common::smoke_mode() {
        vec![1, 4]
    } else if common::full_mode() {
        vec![1, 4, 16, 64]
    } else {
        vec![1, 8]
    };
    let depth_sweep: Vec<usize> = if common::smoke_mode() {
        vec![1, 8]
    } else {
        vec![1, 8, 32]
    };

    for &conns in &conn_sweep {
        for &depth in &depth_sweep {
            let cfg = CoordinatorConfig {
                shards: 4,
                lanes: 2,
                enable_analytics: false, // pure serving-path measurement
                ..Default::default()
            };
            let c = Coordinator::start(cfg).expect("coordinator starts");
            let net = NetServer::start(&NetConfig::default(), c.client()).expect("listener binds");
            let addr = net.local_addr().expect("bound address");

            let window = common::measure_window();
            let hs: Vec<_> = (0..conns)
                .map(|i| {
                    std::thread::spawn(move || {
                        bench::throughput_run(addr, window, depth, 65_536, 1 + i as u64)
                    })
                })
                .collect();
            let mut report = BenchReport::default();
            for h in hs {
                report.merge(&h.join().expect("client panicked").expect("client io"));
            }
            let stats = net.shutdown();
            c.shutdown();

            let rate = report.received as f64 / window.as_secs_f64();
            println!(
                "netbench conns={conns:<3} depth={depth:<3} req_per_s={rate:.0} sheds={} \
                 proto_errs={}",
                report.sheds, stats.protocol_errors
            );
            json.row(
                "throughput",
                &[
                    ("conns", conns as f64),
                    ("depth", depth as f64),
                    ("req_per_s", rate),
                    ("sheds", report.sheds as f64),
                ],
            );
        }
    }
    json.flush();
}

#[cfg(not(target_os = "linux"))]
fn main() {
    // The epoll backend is Linux-only; keep the bench target compiling
    // everywhere so `cargo bench` stays green on other platforms.
    println!("netbench: skipped (no epoll backend on this platform)");
}
