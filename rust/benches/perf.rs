//! §Perf probes: microbenchmarks for every hot path, used to drive the
//! optimization pass (EXPERIMENTS.md §Perf records before/after rows).
//!
//! Rows:
//!   lookup_hit / lookup_miss   — single-thread lookup ns/op at α=20
//!   insert_delete              — paired update ns/op
//!   quiescent_state            — QSBR announcement ns/op
//!   read_lock                  — read-side guard ns/op (should be ~0)
//!   synchronize_rcu            — grace-period latency µs (2 live readers)
//!   rebuild_rate               — rebuild node throughput Mnodes/s
//!   sharded_lookup_hit         — lookup ns/op through the 4-shard facade
//!   rebuild_all_rate           — staggered whole-map rebuild Mnodes/s
//!   detector_batch             — detector-engine ms / 4096-key batch
//!   batch_hash                 — engine pre-hash ms / 4096-key batch
//!
//! Under `DHASH_SMOKE=1` the rows are also written to `BENCH_perf.json`
//! (see `common::BenchJson`) so CI archives the perf trajectory.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use dhash::dhash::{DHashMap, HashFn, ShardedDHash};
use dhash::rcu::{rcu_barrier, synchronize_rcu, RcuThread};
use dhash::runtime::{load_engine, Engine as _, HashKind};
use dhash::util::SplitMix64;

fn ns_per_op(iters: u64, f: impl FnOnce()) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_nanos() as f64 / iters as f64
}

fn main() {
    common::print_host_table1();
    let mut json = common::BenchJson::new("perf");
    let iters: u64 = if common::smoke_mode() {
        60_000
    } else if common::full_mode() {
        3_000_000
    } else {
        600_000
    };

    // Table at α = 20: 1024 buckets, 20480 keys.
    let g = RcuThread::register();
    let map = DHashMap::with_buckets(1024, 0x5eed);
    let nkeys = 20_480u64;
    for k in 0..nkeys {
        map.insert(&g, k, k).unwrap();
    }

    let mut rng = SplitMix64::new(1);
    let ns = ns_per_op(iters, || {
        for _ in 0..iters {
            let k = rng.next_bounded(nkeys);
            std::hint::black_box(map.lookup(&g, k));
        }
    });
    println!("perf lookup_hit ns_per_op={ns:.1} mops={:.2}", 1e3 / ns);
    json.row("lookup_hit", &[("ns_per_op", ns), ("mops", 1e3 / ns)]);

    let mut rng = SplitMix64::new(2);
    let ns = ns_per_op(iters, || {
        for _ in 0..iters {
            let k = nkeys + rng.next_bounded(nkeys);
            std::hint::black_box(map.lookup(&g, k));
        }
    });
    println!("perf lookup_miss ns_per_op={ns:.1} mops={:.2}", 1e3 / ns);
    json.row("lookup_miss", &[("ns_per_op", ns), ("mops", 1e3 / ns)]);

    let upd_iters = iters / 4;
    let mut rng = SplitMix64::new(3);
    let ns = ns_per_op(upd_iters * 2, || {
        for _ in 0..upd_iters {
            let k = nkeys + 1 + rng.next_bounded(nkeys);
            std::hint::black_box(map.insert(&g, k, k).is_ok());
            std::hint::black_box(map.delete(&g, k));
        }
    });
    println!("perf insert_delete ns_per_op={ns:.1} mops={:.2}", 1e3 / ns);
    json.row("insert_delete", &[("ns_per_op", ns), ("mops", 1e3 / ns)]);

    // Atomic overwrite (the coordinator's Put path): an in-place value
    // swap on the live node, cheaper than the delete+insert it replaced.
    let mut rng = SplitMix64::new(4);
    let ns = ns_per_op(upd_iters, || {
        for _ in 0..upd_iters {
            let k = rng.next_bounded(nkeys);
            std::hint::black_box(map.upsert(&g, k, k + 1));
        }
    });
    println!("perf upsert_overwrite ns_per_op={ns:.1} mops={:.2}", 1e3 / ns);
    json.row("upsert_overwrite", &[("ns_per_op", ns), ("mops", 1e3 / ns)]);

    let ns = ns_per_op(iters, || {
        for _ in 0..iters {
            g.quiescent_state();
        }
    });
    println!("perf quiescent_state ns_per_op={ns:.2}");
    json.row("quiescent_state", &[("ns_per_op", ns)]);

    let ns = ns_per_op(iters, || {
        for _ in 0..iters {
            let guard = g.read_lock();
            std::hint::black_box(&guard);
        }
    });
    println!("perf read_lock ns_per_op={ns:.2}");
    json.row("read_lock", &[("ns_per_op", ns)]);

    // Grace-period latency with two actively-quiescing readers.
    {
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..2 {
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let t = RcuThread::register();
                while !stop.load(Ordering::Relaxed) {
                    t.quiescent_state();
                    std::hint::spin_loop();
                }
                t.offline();
            }));
        }
        let rounds = if common::smoke_mode() {
            50
        } else if common::full_mode() {
            2000
        } else {
            400
        };
        let t0 = Instant::now();
        for _ in 0..rounds {
            synchronize_rcu();
        }
        let us = t0.elapsed().as_micros() as f64 / rounds as f64;
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        println!("perf synchronize_rcu us_per_gp={us:.2} (2 live readers)");
        json.row("synchronize_rcu", &[("us_per_gp", us)]);
    }

    // Rebuild throughput (no concurrent workers: pure migration rate).
    {
        let n: u64 = if common::smoke_mode() {
            20_000
        } else if common::full_mode() {
            400_000
        } else {
            100_000
        };
        let m2 = DHashMap::with_buckets(1024, 1);
        for k in 0..n {
            m2.insert(&g, k, k).unwrap();
        }
        let t0 = Instant::now();
        m2.rebuild(&g, 2048, HashFn::Seeded(2)).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "perf rebuild_rate mnodes_per_s={:.3} ({n} nodes in {:.1} ms)",
            n as f64 / dt / 1e6,
            dt * 1e3
        );
        json.row("rebuild_rate", &[("mnodes_per_s", n as f64 / dt / 1e6)]);
    }

    // Sharded-facade rows: routing overhead on the lookup hot path, and
    // the staggered whole-map rebuild rate (4 shards, same α=20 budget).
    {
        let sm = ShardedDHash::with_buckets(4, 256, 0x5eed);
        for k in 0..nkeys {
            sm.insert(&g, k, k).unwrap();
        }
        let mut rng = SplitMix64::new(9);
        let ns = ns_per_op(iters, || {
            for _ in 0..iters {
                let k = rng.next_bounded(nkeys);
                std::hint::black_box(sm.lookup(&g, k));
            }
        });
        println!(
            "perf sharded_lookup_hit ns_per_op={ns:.1} mops={:.2} (4 shards)",
            1e3 / ns
        );
        json.row("sharded_lookup_hit", &[("ns_per_op", ns), ("mops", 1e3 / ns)]);

        let t0 = Instant::now();
        sm.rebuild_all(&g, 512, HashFn::Seeded(2)).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "perf rebuild_all_rate mnodes_per_s={:.3} ({nkeys} nodes, 4 staggered shards, \
             {:.1} ms)",
            nkeys as f64 / dt / 1e6,
            dt * 1e3
        );
        json.row("rebuild_all_rate", &[("mnodes_per_s", nkeys as f64 / dt / 1e6)]);
    }

    // Detector-engine latencies (control-path budget: must stay ~ms).
    {
        let engine = load_engine().expect("default engine always loads");
        let keys: Vec<u64> = (0..engine.batch() as u64).collect();
        // Warm up caches.
        engine.detect(&keys, 1, 4096, HashKind::Seeded).unwrap();
        engine.batch_hash(&keys, 1, 4096, HashKind::Seeded).unwrap();
        let rounds = if common::full_mode() { 200 } else { 50 };
        let t0 = Instant::now();
        for _ in 0..rounds {
            std::hint::black_box(engine.detect(&keys, 1, 4096, HashKind::Seeded).unwrap());
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / rounds as f64;
        println!(
            "perf detector_batch ms_per_batch={ms:.3} (engine={} batch={})",
            engine.name(),
            engine.batch()
        );
        json.row("detector_batch", &[("ms_per_batch", ms)]);
        let t0 = Instant::now();
        for _ in 0..rounds {
            std::hint::black_box(engine.batch_hash(&keys, 1, 4096, HashKind::Seeded).unwrap());
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / rounds as f64;
        println!(
            "perf batch_hash ms_per_batch={ms:.3} (engine={} batch={})",
            engine.name(),
            engine.batch()
        );
        json.row("batch_hash", &[("ms_per_batch", ms)]);
    }

    json.flush();
    g.quiescent_state();
    rcu_barrier();
}
