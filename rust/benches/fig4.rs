//! Figure 4 regenerator: DHash scalability across load factors
//! (α ∈ {20, 50, 100, 200}) on the paper's "other architectures".
//!
//! SUBSTITUTION (DESIGN.md): the paper ran these sweeps on IBM POWER9
//! (fig4a, 16 cores) and Cavium ARMv8 (fig4b, 96 cores). Cross-ISA runs
//! are impossible in this container, so both panels are regenerated on
//! the host with the panel's thread range (POWER9: up to 32 = 2x16;
//! ARMv8: up to 96), measuring oversubscription behaviour. The property
//! under test carries over: DHash's throughput rises ~linearly then
//! *stays flat or keeps rising* past core count, never collapsing, at
//! every load factor.

mod common;

use common::{fig2_cell, full_mode, print_host_table1, row};

fn main() {
    print_host_table1();
    let alphas = [20usize, 50, 100, 200];
    let panels: [(&str, Vec<usize>); 2] = if full_mode() {
        [
            ("fig4a", vec![1, 2, 4, 8, 16, 24, 32]),
            ("fig4b", vec![1, 2, 4, 8, 16, 32, 64, 96]),
        ]
    } else {
        [("fig4a", vec![1, 2, 4]), ("fig4b", vec![1, 4, 8])]
    };
    for (fig, threads) in panels {
        let arch = if fig == "fig4a" { "POWER9-substitute" } else { "ARMv8-substitute" };
        println!("# {fig} ({arch}): DHash throughput, 90% lookups");
        for alpha in alphas {
            for &t in &threads {
                let s = fig2_cell("dhash", t, 90, alpha);
                row(fig, &format!("HT-DHash-{alpha}"), t, &s);
            }
        }
    }
    println!("# check: throughput must not collapse once threads oversubscribe cores.");
}
