//! §Ingest scalability: coordinator throughput through the
//! completion-based `KvClient` API, swept over ingest lanes
//! {1, 4, workers} × client threads. Each client pipelines batch
//! tickets (submission depth > 1), so the sweep measures exactly what
//! the multi-lane redesign buys: with one lane the single batcher
//! serializes ahead of the shards (PR 2's `shard_scale` finding); with
//! N lanes the batchers drain in parallel.
//!
//! Under `DHASH_SMOKE=1` the rows are also written to
//! `BENCH_ingest.json` (see `common::BenchJson`), picked up by the CI
//! `bench-smoke-json` artifact glob.

mod common;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dhash::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, PreRoute, Request};
use dhash::dhash::HashFn;
use dhash::util::SplitMix64;

/// KV worker threads (also the top of the lane sweep, per the lanes ∈
/// {1, 4, workers} protocol).
const WORKERS: usize = 2;
/// Tickets in flight per client before the oldest is resolved.
const PIPELINE_DEPTH: usize = 4;
const BATCH: usize = 64;

fn main() {
    common::print_host_table1();
    let mut json = common::BenchJson::new("ingest");

    let mut lane_sweep = vec![1usize, 4, WORKERS];
    lane_sweep.sort_unstable();
    lane_sweep.dedup();

    for &lanes in &lane_sweep {
        for &clients in &common::thread_sweep() {
            let cfg = CoordinatorConfig {
                nbuckets: 1024,
                hash: HashFn::Seeded(0x5eed),
                shards: 4,
                lanes,
                workers: WORKERS,
                batcher: BatcherConfig {
                    max_batch: BATCH,
                    max_wait: Duration::from_micros(200),
                    pre_route: PreRoute::Off,
                },
                enable_analytics: false, // pure ingest-path measurement
                ..Default::default()
            };
            let c = Arc::new(Coordinator::start(cfg).expect("coordinator starts"));

            let stop = Arc::new(AtomicBool::new(false));
            let done = Arc::new(AtomicU64::new(0));
            let mut hs = Vec::new();
            for t in 0..clients {
                let c2 = c.clone();
                let stop = stop.clone();
                let done = done.clone();
                hs.push(std::thread::spawn(move || {
                    let kv = c2.client();
                    let mut rng = SplitMix64::new(t as u64 + 1);
                    let mut inflight = std::collections::VecDeque::new();
                    while !stop.load(Ordering::Relaxed) {
                        let reqs: Vec<Request> = (0..BATCH)
                            .map(|_| {
                                let k = rng.next_bounded(1_000_000);
                                if rng.next_f64() < 0.9 {
                                    Request::get(k)
                                } else {
                                    Request::put(k, k)
                                }
                            })
                            .collect();
                        let Ok(ticket) = kv.submit_batch(&reqs) else {
                            break;
                        };
                        inflight.push_back(ticket);
                        if inflight.len() >= PIPELINE_DEPTH {
                            let oldest = inflight.pop_front().unwrap();
                            if oldest.wait().is_ok() {
                                done.fetch_add(BATCH as u64, Ordering::Relaxed);
                            }
                        }
                    }
                    // Drain the tail of the pipeline.
                    for ticket in inflight {
                        if ticket.wait().is_ok() {
                            done.fetch_add(BATCH as u64, Ordering::Relaxed);
                        }
                    }
                }));
            }

            let window = common::measure_window();
            std::thread::sleep(window);
            stop.store(true, Ordering::Relaxed);
            // Snapshot before joining: the post-stop pipeline drain
            // completes work outside the window and must not count
            // toward the window's rate.
            let reqs = done.load(Ordering::Relaxed);
            for h in hs {
                h.join().unwrap();
            }
            let rate = reqs as f64 / window.as_secs_f64();
            println!(
                "ingest_scale lanes={lanes:<3} clients={clients:<3} depth={PIPELINE_DEPTH} \
                 req_per_s={rate:.0}"
            );
            json.row(
                "throughput",
                &[
                    ("lanes", lanes as f64),
                    ("clients", clients as f64),
                    ("req_per_s", rate),
                ],
            );
            c.shutdown();
        }
    }
    json.flush();
}
