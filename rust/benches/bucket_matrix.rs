//! Bucket-algorithm matrix: every `BucketSet` backend (michael /
//! spinlock / cow / split-ordered) crossed with the paper's two load
//! regimes (α = 20 and α = 200) under three scenarios:
//!
//!   uniform       — steady state, 90% lookups, strong keyed hash.
//!   attack        — `HashFn::Modulo` with congruent keys: the whole
//!                   population collides into one DHash bucket, so the
//!                   cell measures the backend's intra-bucket structure
//!                   (split-ordered grows its local sentinel directory;
//!                   the list backends degrade linearly).
//!   rebuild-storm — the §6.2 continuous-rebuild protocol racing the
//!                   measured ops.
//!
//! This is the ablation the modularity claim rests on: which backend
//! wins where, measured under one harness. Under `DHASH_SMOKE=1` the
//! matrix is emitted as `BENCH_buckets.json` for the CI artifact trail.

mod common;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use common::{measure_window, repeats, BenchJson};
use dhash::baselines::ConcurrentMap;
use dhash::dhash::{DHashMap, HashFn};
use dhash::lflist::{CowSortedArray, MichaelList, SpinlockList, SplitOrderedList};
use dhash::rcu::{rcu_barrier, RcuThread};
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};
use dhash::util::{SplitMix64, Summary};

const BACKENDS: [&str; 4] = ["michael", "spinlock", "cow", "splitord"];
const NBUCKETS: usize = 64;

fn make_backend(name: &str, hash: HashFn) -> Arc<dyn ConcurrentMap> {
    match name {
        "michael" => Arc::new(DHashMap::<MichaelList>::with_hash(NBUCKETS, hash)),
        "spinlock" => Arc::new(DHashMap::<SpinlockList>::with_hash(NBUCKETS, hash)),
        "cow" => Arc::new(DHashMap::<CowSortedArray>::with_hash(NBUCKETS, hash)),
        "splitord" => Arc::new(DHashMap::<SplitOrderedList>::with_hash(NBUCKETS, hash)),
        _ => unreachable!("unknown backend {name}"),
    }
}

fn torture_cfg(alpha: usize, rebuild: RebuildMode) -> TortureConfig {
    TortureConfig {
        threads: 2,
        mix: OpMix::lookup_pct(90),
        alpha,
        nbuckets: NBUCKETS,
        key_range: 0, // auto 2·α·β: stationary population at α·β
        duration: measure_window(),
        rebuild,
        pin: false,
        seed: 17,
        hash_seed: 5,
    }
    .clamped_for_smoke()
}

/// One torture-driven cell (uniform / rebuild-storm).
fn torture_cell(backend: &str, alpha: usize, rebuild: RebuildMode) -> Summary {
    let map = make_backend(backend, HashFn::Seeded(5));
    let cfg = torture_cfg(alpha, rebuild);
    Summary::of(&torture::measure_mops(map, &cfg, repeats()))
}

/// The attack cell: weak `Modulo` hash, every key congruent to 0 mod β,
/// so all α·β live nodes share one outer bucket and the measurement is
/// the backend's behaviour at its own load threshold, not the table's.
fn attack_cell(backend: &str, alpha: usize) -> Summary {
    let samples: Vec<f64> = (0..repeats())
        .map(|_| {
            let map = make_backend(backend, HashFn::Modulo);
            let n = (alpha * NBUCKETS) as u64;
            {
                let g = RcuThread::register();
                for i in 0..n {
                    map.insert(&g, i * NBUCKETS as u64, i);
                }
                g.quiescent_state();
            }
            let stop = Arc::new(AtomicBool::new(false));
            let total = Arc::new(AtomicU64::new(0));
            let mut workers = Vec::new();
            for t in 0..2u64 {
                let map = map.clone();
                let stop = stop.clone();
                let total = total.clone();
                workers.push(std::thread::spawn(move || {
                    let g = RcuThread::register();
                    let mut rng = SplitMix64::new(t + 31);
                    let mut ops = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        for _ in 0..32 {
                            let i = rng.next_bounded(2 * n);
                            let k = i * NBUCKETS as u64; // stays congruent
                            if rng.next_bounded(10) == 0 {
                                // 10% write churn on the colliding set.
                                if !map.insert(&g, k, k) {
                                    map.delete(&g, k);
                                }
                            } else {
                                let _ = map.lookup(&g, k);
                            }
                            ops += 1;
                        }
                        g.quiescent_state();
                    }
                    total.fetch_add(ops, Ordering::Relaxed);
                    g.offline();
                }));
            }
            let window = measure_window();
            std::thread::sleep(window);
            stop.store(true, Ordering::Relaxed);
            for w in workers {
                w.join().unwrap();
            }
            rcu_barrier();
            total.load(Ordering::Relaxed) as f64 / window.as_secs_f64() / 1e6
        })
        .collect();
    Summary::of(&samples)
}

fn main() {
    common::print_host_table1();
    println!("# bucket matrix: backend x alpha {{20, 200}} x scenario");
    let mut json = BenchJson::new("buckets");
    for backend in BACKENDS {
        for alpha in [20usize, 200] {
            let cells: [(&str, Summary); 3] = [
                ("uniform", torture_cell(backend, alpha, RebuildMode::None)),
                ("attack", attack_cell(backend, alpha)),
                (
                    "rebuild-storm",
                    torture_cell(
                        backend,
                        alpha,
                        RebuildMode::Continuous { alt_nbuckets: NBUCKETS * 2 },
                    ),
                ),
            ];
            for (scenario, s) in cells {
                println!(
                    "buckets backend={backend:<9} alpha={alpha:<4} scenario={scenario:<13} \
                     mops_mean={:<8.3} mops_stddev={:.3}",
                    s.mean, s.stddev
                );
                json.row(
                    &format!("{backend}/{scenario}"),
                    &[
                        ("alpha", alpha as f64),
                        ("mops_mean", s.mean),
                        ("mops_stddev", s.stddev),
                    ],
                );
            }
        }
    }
    json.flush();
}
