//! Figure 2 regenerator: overall throughput of the four hash tables under
//! the continuous-rebuild protocol (§6.2), across worker-thread counts.
//!
//! Panels:
//!   fig2a  90% lookup, α=20      fig2b  80% lookup, α=20
//!   fig2c  90% lookup, α=50      fig2d  80% lookup, α=50
//!   fig2e  90% lookup, α=200     fig2f  80% lookup, α=200
//!
//! Also prints the paper's headline ratios (§1/§6.2): DHash vs each
//! baseline at the highest thread count, for α=20 and α=200.
//!
//! Quick sweep by default; `DHASH_BENCH_FULL=1 cargo bench --bench fig2`
//! (or `-- --full`) for the paper-scale sweep.

mod common;

use common::{fig2_cell, print_host_table1, row, thread_sweep, TABLES};
use std::collections::HashMap;

fn main() {
    print_host_table1();
    let panels = [
        ("fig2a", 90u8, 20usize),
        ("fig2b", 80, 20),
        ("fig2c", 90, 50),
        ("fig2d", 80, 50),
        ("fig2e", 90, 200),
        ("fig2f", 80, 200),
    ];
    let threads = thread_sweep();
    let tmax = *threads.last().unwrap();
    // (panel, table) -> mops at max threads, for the headline ratios.
    let mut at_max: HashMap<(&str, &str), f64> = HashMap::new();

    for (fig, lookup, alpha) in panels {
        println!("# {fig}: {lookup}% lookup, load factor {alpha}");
        for table in TABLES {
            for &t in &threads {
                let s = fig2_cell(table, t, lookup, alpha);
                row(fig, table, t, &s);
                if t == tmax {
                    at_max.insert((fig, table), s.mean);
                }
            }
        }
    }

    println!("# headline ratios (DHash / baseline at {tmax} threads):");
    for (fig, alpha) in [("fig2a", 20), ("fig2b", 20), ("fig2e", 200), ("fig2f", 200)] {
        let d = at_max[&(fig, "dhash")];
        let r = |b: &str| d / at_max[&(fig, b)].max(1e-9);
        println!(
            "{fig} alpha={alpha}: DHash/Split={:.2}x DHash/Xu={:.2}x DHash/RHT={:.2}x \
             (paper: 1.4-2.0x at alpha=20; 2.3-6.2x at alpha=200)",
            r("split"),
            r("xu"),
            r("rht")
        );
    }
}
