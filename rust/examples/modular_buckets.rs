//! Modularity (paper goal 2): run the same DHash algorithm over three
//! different bucket set implementations and compare their torture
//! throughput — the progress-guarantee / performance / engineering
//! trade-off the paper describes, made concrete.
//!
//! ```sh
//! cargo run --release --example modular_buckets [-- --secs 1.0]
//! ```

use std::sync::Arc;
use std::time::Duration;

use dhash::baselines::ConcurrentMap;
use dhash::dhash::{DHashMap, HashFn};
use dhash::lflist::{CowSortedArray, MichaelList, SpinlockList};
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};
use dhash::util::cli::Args;
use dhash::util::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["secs", "threads", "alpha"])?;
    let secs = args.get_or("secs", 0.5f64)?;
    let cfg = TortureConfig {
        threads: args.get_or("threads", 4usize)?,
        mix: OpMix::lookup_pct(90),
        alpha: args.get_or("alpha", 20usize)?,
        nbuckets: 512,
        key_range: 500_000,
        duration: Duration::from_secs_f64(secs),
        rebuild: RebuildMode::Continuous { alt_nbuckets: 1024 },
        pin: true,
        seed: 42,
        hash_seed: 7,
    };

    let variants: Vec<(&str, Arc<dyn ConcurrentMap>)> = vec![
        (
            "MichaelList (lock-free, the paper's default)",
            Arc::new(DHashMap::<MichaelList>::with_hash(
                cfg.nbuckets,
                HashFn::Seeded(cfg.hash_seed),
            )),
        ),
        (
            "SpinlockList (blocking, simplest)",
            Arc::new(DHashMap::<SpinlockList>::with_hash(
                cfg.nbuckets,
                HashFn::Seeded(cfg.hash_seed),
            )),
        ),
        (
            "CowSortedArray (wait-free reads, COW writes)",
            Arc::new(DHashMap::<CowSortedArray>::with_hash(
                cfg.nbuckets,
                HashFn::Seeded(cfg.hash_seed),
            )),
        ),
    ];

    println!(
        "DHash bucket-algorithm ablation: {} threads, alpha={}, 90% lookups, continuous rebuild",
        cfg.threads, cfg.alpha
    );
    for (name, map) in variants {
        let samples = torture::measure_mops(map, &cfg, 3);
        let s = Summary::of(&samples);
        println!("  {name:<48} {:>8.3} ± {:.3} Mop/s", s.mean, s.stddev);
    }
    println!("modular_buckets OK");
    Ok(())
}
