//! Quickstart: the DHash public API in ~50 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dhash::dhash::{DHashMap, HashFn};
use dhash::rcu::RcuThread;

fn main() {
    // Every thread that touches the table registers with RCU once and
    // announces quiescent states between operations (QSBR).
    let guard = RcuThread::register();

    // A table with 1024 buckets using the seeded mix64 hash family.
    let map = DHashMap::with_buckets(1024, 0xdead_beef);

    // Plain concurrent-map operations.
    for k in 0..10_000u64 {
        map.insert(&guard, k, k * k).unwrap();
    }
    assert_eq!(map.lookup(&guard, 77), Some(77 * 77));
    assert!(map.delete(&guard, 77));
    assert_eq!(map.lookup(&guard, 77), None);
    println!("inserted 10k keys, lookup/delete OK, len = {}", map.len(&guard));

    // The paper's party trick: replace the hash function *on the fly*.
    // Other threads could keep reading and writing while this runs.
    let stats = map
        .rebuild(&guard, 4096, HashFn::Seeded(0x1234_5678))
        .expect("no concurrent rebuild");
    println!("rebuild: {stats}");

    // Everything is still there, now placed by the new function.
    assert_eq!(map.lookup(&guard, 78), Some(78 * 78));
    assert_eq!(map.len(&guard), 9_999);
    assert_eq!(map.nbuckets(&guard), 4096);

    // Load-factor diagnostics (what the coordinator's detector watches).
    let loads = map.bucket_loads(&guard);
    let max = loads.iter().max().unwrap();
    println!(
        "bucket loads after rebuild: max={} mean={:.2}",
        max,
        9_999.0 / 4096.0
    );

    guard.quiescent_state();
    println!("quickstart OK");
}
