//! The workload that created the first dynamic hash table: Herbert Xu's
//! 2010 rebuildable table managed *fragment/flow state* in the Linux
//! kernel's networking stack, where bursts of fragmented packets (or a
//! DoS) can flood the table far past its design load factor (§1, §2).
//!
//! This example simulates that scenario on DHash: a flow table keyed by
//! (src, dst, id)-style u64 flow ids, zipf-skewed steady traffic, and a
//! periodic *fragment burst* that multiplies the live population. An
//! operator loop watches the observed load factor and reacts by
//! rebuilding to a larger bucket array (and back after the burst drains)
//! — the "resize" half of DHash's dynamism, complementing the
//! hash-change half shown in `attack_mitigation`.
//!
//! ```sh
//! cargo run --release --example fragment_reassembly -- [--secs 8]
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dhash::dhash::{DHashMap, HashFn};
use dhash::rcu::RcuThread;
use dhash::torture::Zipf;
use dhash::util::cli::Args;
use dhash::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["secs", "flows"])?;
    let secs: u64 = args.get_or("secs", 8u64)?;
    let flows: u64 = args.get_or("flows", 200_000u64)?;

    let map = Arc::new(DHashMap::with_buckets(1024, 0x5eed));
    let stop = Arc::new(AtomicBool::new(false));

    // Traffic: zipf-skewed flow activity + a burst window each ~3s that
    // floods short-lived fragment entries.
    let traffic = {
        let map = map.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let g = RcuThread::register();
            let zipf = Zipf::new(flows, 1.1);
            let mut rng = SplitMix64::new(3);
            let mut frag_seq = flows; // fragment keys above the flow space
            let t0 = Instant::now();
            while !stop.load(Ordering::Relaxed) {
                let in_burst = (t0.elapsed().as_millis() / 1000) % 3 == 2;
                for _ in 0..512 {
                    if in_burst {
                        // Burst: insert fragment state, rarely cleaned.
                        frag_seq += 1;
                        let _ = map.insert(&g, frag_seq, 1);
                        if frag_seq % 4 == 0 {
                            map.delete(&g, frag_seq - 2);
                        }
                    } else {
                        // Steady state: touch a zipf-ranked flow.
                        let flow = zipf.sample(&mut rng);
                        if map.lookup(&g, flow).is_none() {
                            let _ = map.insert(&g, flow, 0);
                        }
                        // Age out a random old fragment if any.
                        if frag_seq > flows {
                            map.delete(&g, map_key_to_age(&mut rng, flows, frag_seq));
                        }
                    }
                }
                g.quiescent_state();
            }
            g.offline();
        })
    };

    // Operator loop: keep the observed load factor in [2, 16] by
    // rebuilding (grow on burst, shrink when it drains).
    let g = RcuThread::register();
    println!(
        "{:>5} {:>10} {:>9} {:>8} {:>9}",
        "t(s)", "entries", "buckets", "load", "action"
    );
    let t0 = Instant::now();
    let mut next_seed = 1u64;
    while t0.elapsed().as_secs() < secs {
        // Sleep in an extended quiescent state: an online-but-sleeping
        // registered thread would stall the reclaimer's grace periods.
        g.offline_while(|| std::thread::sleep(Duration::from_millis(500)));
        let entries = map.len(&g);
        let buckets = map.nbuckets(&g);
        let load = entries as f64 / buckets as f64;
        let action = if load > 16.0 {
            next_seed += 1;
            let nb = (entries / 4).next_power_of_two().max(1024);
            map.rebuild(&g, nb, HashFn::Seeded(next_seed)).ok();
            format!("grow -> {nb}")
        } else if load < 2.0 && buckets > 1024 {
            next_seed += 1;
            let nb = (entries / 4).next_power_of_two().max(1024);
            map.rebuild(&g, nb, HashFn::Seeded(next_seed)).ok();
            format!("shrink -> {nb}")
        } else {
            "-".to_string()
        };
        println!(
            "{:>5.1} {:>10} {:>9} {:>8.2} {:>9}",
            t0.elapsed().as_secs_f64(),
            entries,
            buckets,
            load,
            action
        );
        g.quiescent_state();
    }
    stop.store(true, Ordering::Relaxed);
    g.offline_while(|| traffic.join()).unwrap();
    println!(
        "final: {} entries in {} buckets after {} rebuilds",
        map.len(&g),
        map.nbuckets(&g),
        map.rebuild_count()
    );
    println!("fragment_reassembly OK");
    Ok(())
}

/// Pick an old fragment key to expire (uniform over the fragment range).
fn map_key_to_age(rng: &mut SplitMix64, flows: u64, frag_seq: u64) -> u64 {
    if frag_seq <= flows + 1 {
        flows + 1
    } else {
        flows + 1 + rng.next_bounded(frag_seq - flows)
    }
}
