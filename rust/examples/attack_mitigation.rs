//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full three-layer system
//! on a real serving workload.
//!
//! A KV service (L3 coordinator over DHash) starts with a *weak* modulo
//! hash. Client threads send batched GET/PUT traffic; partway through, an
//! adversary floods PUTs whose keys all collide under the weak hash
//! (Crosby–Wallach complexity attack). The analytics thread — evaluating
//! the detector kernels through the configured [`dhash::runtime::Engine`]
//! backend (native by default; `DHASH_ENGINE=pjrt` for the AOT JAX/Pallas
//! artifacts) — watches the sampled key stream's chi², flags the attack,
//! and the controller rebuilds the table with a fresh seeded hash
//! *without stopping the service*. The run reports a per-interval
//! timeline of throughput, p50/p99 latency, and chi², plus the mitigation
//! events.
//!
//! Runs on a clean checkout: no artifacts and no Python toolchain needed.
//!
//! With `--shards N` (default 4) the service runs the sharded map and the
//! adversary aims its flood at ONE shard: the per-shard chi² verdict
//! trips only there and the mitigation rebuilds only the victim shard —
//! 1/N of the keys migrate while the other shards serve untouched.
//! `--shards 1` reproduces the original whole-table demo.
//!
//! Clients drive the completion-based ingest API: each thread takes a
//! `KvClient` from the coordinator, submits its batch as a ticket over
//! the `--lanes` (default 4) independent ingest lanes, and resolves the
//! ticket — the measured latency is submit→completion.
//!
//! ```sh
//! cargo run --release --example attack_mitigation -- \
//!     [--secs 12] [--attack-at 4] [--clients 2] [--shards 4] [--lanes 4] \
//!     [--no-analytics]
//! ```

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dhash::coordinator::{
    BatcherConfig, ControllerConfig, Coordinator, CoordinatorConfig, DetectorConfig, PreRoute,
    Request,
};
use dhash::dhash::HashFn;
use dhash::torture::{AttackGen, ShardedAttackGen};
use dhash::util::stats::percentile;
use dhash::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    let args = dhash::util::cli::Args::from_env(&[
        "secs",
        "attack-at",
        "clients",
        "shards",
        "lanes",
        "no-analytics",
    ])?;
    let secs: u64 = args.get_or("secs", 12u64)?;
    let attack_at: u64 = args.get_or("attack-at", 4u64)?;
    let nclients: usize = args.get_or("clients", 2usize)?;
    let shards: usize = args.get_or("shards", 4usize)?;
    let lanes: usize = args.get_or("lanes", 4usize)?;
    let analytics = !args.get_bool("no-analytics");
    anyhow::ensure!(
        shards >= 1 && shards.is_power_of_two(),
        "--shards must be a power of two"
    );
    anyhow::ensure!(
        lanes >= 1 && lanes.is_power_of_two(),
        "--lanes must be a power of two"
    );
    // The adversary concentrates on one shard (the targeted-mitigation
    // demo); with --shards 1 this is the whole table.
    let victim = shards - 1;

    let nbuckets = 4096usize; // per shard
    let cfg = CoordinatorConfig {
        nbuckets,
        // Deliberately weak: the attacker knows bucket = key % nbuckets.
        hash: HashFn::Modulo,
        shards,
        lanes,
        workers: 2,
        batcher: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            pre_route: PreRoute::Off,
        },
        detector: DetectorConfig {
            sample_capacity: 4096,
            period: Duration::from_millis(50),
            sigma: 8.0,
            min_samples: 1024,
        },
        controller: ControllerConfig {
            cooldown: Duration::from_secs(2),
            rebuild_buckets: None,
        },
        elastic: None,
        enable_analytics: analytics,
    };
    eprintln!(
        "attack_mitigation: {shards} shard(s) x {nbuckets} buckets, {lanes} ingest lane(s), \
         weak modulo hash, attack on shard {victim} at t={attack_at}s, analytics={analytics}"
    );
    let coord = Arc::new(Coordinator::start(cfg)?);

    let stop = Arc::new(AtomicBool::new(false));
    let completed = Arc::new(AtomicU64::new(0));
    // Latency samples (µs), drained each interval by the reporter.
    let latencies = Arc::new(Mutex::new(Vec::<f64>::new()));
    let t0 = Instant::now();

    let mut clients = Vec::new();
    for c in 0..nclients {
        let coord = coord.clone();
        let stop = stop.clone();
        let completed = completed.clone();
        let latencies = latencies.clone();
        clients.push(std::thread::spawn(move || {
            // Per-thread submission handle: no lock shared with the
            // other clients, requests fan out across the ingest lanes.
            let kv = coord.client();
            let mut rng = SplitMix64::new(c as u64 + 1);
            // All clients aim at the same victim shard (sharded mode).
            let mut attack: Box<dyn Iterator<Item = u64>> = if shards > 1 {
                Box::new(ShardedAttackGen::new(nbuckets, 7 + c as u64, shards, victim))
            } else {
                Box::new(AttackGen::new(nbuckets, 7 + c as u64))
            };
            let t0 = Instant::now();
            while !stop.load(Ordering::Relaxed) {
                let attacking = t0.elapsed().as_secs() >= attack_at;
                let reqs: Vec<Request> = (0..64)
                    .map(|_| {
                        if attacking && rng.next_f64() < 0.8 {
                            // Flood: colliding keys under key % nbuckets.
                            Request::put(attack.next().unwrap(), 0)
                        } else {
                            let k = rng.next_bounded(1_000_000);
                            if rng.next_f64() < 0.9 {
                                Request::get(k)
                            } else {
                                Request::put(k, k)
                            }
                        }
                    })
                    .collect();
                let t = Instant::now();
                let n = reqs.len() as u64;
                // Submit → ticket → wait: the measured latency is the
                // full submit-to-completion path.
                let Ok(ticket) = kv.submit_batch(&reqs) else { break };
                if ticket.wait().is_err() {
                    break; // shut down mid-flight
                }
                let us = t.elapsed().as_secs_f64() * 1e6 / n as f64;
                completed.fetch_add(n, Ordering::Relaxed);
                latencies.lock().unwrap().push(us);
            }
        }));
    }

    println!(
        "{:>4} {:>12} {:>10} {:>10} {:>12} {:>9}",
        "t(s)", "req/s", "p50(µs)", "p99(µs)", "chi2", "rebuilds"
    );
    let mut last = 0u64;
    for sec in 0..secs {
        std::thread::sleep(Duration::from_secs(1));
        let total = completed.load(Ordering::Relaxed);
        let rate = total - last;
        last = total;
        let mut lat = latencies.lock().unwrap();
        let mut samples: Vec<f64> = lat.drain(..).collect();
        drop(lat);
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let st = coord.stats();
        let marker = if sec + 1 == attack_at { "  <-- attack begins" } else { "" };
        println!(
            "{:>4} {:>12} {:>10.1} {:>10.1} {:>12.1} {:>9}{}",
            sec + 1,
            rate,
            percentile(&samples, 0.50),
            percentile(&samples, 0.99),
            st.last_chi2,
            st.rebuilds,
            marker
        );
    }
    stop.store(true, Ordering::Relaxed);
    for c in clients {
        c.join().unwrap();
    }

    let events = coord.rebuild_events();
    if analytics {
        println!("\nmitigation events:");
        for ev in &events {
            println!(
                "  t={:>6.2?}  shard={}  chi2={:>10.1}  installed {:?}  moved {} nodes in {:?}",
                ev.at, ev.shard, ev.chi2, ev.new_hash, ev.moved, ev.elapsed
            );
        }
        if events.is_empty() {
            println!("  (none — was the attack window long enough?)");
        } else if shards > 1 && events.iter().all(|e| e.shard == victim) {
            println!(
                "\nattack detected and mitigated while serving: OK \
                 (only shard {victim} of {shards} migrated)"
            );
        } else {
            println!("\nattack detected and mitigated while serving: OK");
        }
        let per_shard = coord.stats().last_chi2_per_shard;
        if per_shard.len() > 1 {
            println!("final per-shard chi2: {per_shard:.1?}");
        }
    } else {
        println!("\nanalytics disabled: attack ran unmitigated (baseline mode)");
    }
    let elapsed = t0.elapsed();
    let st = coord.stats();
    println!(
        "total: {} requests in {:?} ({:.0} req/s), {} batches, {} rebuilds",
        st.total_requests,
        elapsed,
        st.total_requests as f64 / elapsed.as_secs_f64(),
        st.total_batches,
        st.rebuilds
    );
    coord.shutdown();
    Ok(())
}
