#!/usr/bin/env bash
# SeqCst budget check for the concurrency core (rust/src/{dhash,lflist,rcu}).
#
# Every `Ordering::SeqCst` in the audited tree must be accounted for in
# tools/seqcst_allowlist.txt (per-file counts). The ordering audit
# relaxed the read paths to documented Acquire/Release/Relaxed pairs
# (DESIGN.md §Memory orderings); the few SeqCst sites that remain are
# writer-side protocol stores and test-local flags. A NEW SeqCst site —
# or one that moves between files — fails this check until the allowlist
# and the DESIGN.md table are updated to explain it.
set -euo pipefail
cd "$(dirname "$0")/.."

allow=tools/seqcst_allowlist.txt
scope=(rust/src/dhash rust/src/lflist rust/src/rcu)
fail=0

declare -A want
while read -r path count; do
    [[ -z "$path" || "$path" == \#* ]] && continue
    want["$path"]=$count
done <"$allow"

declare -A got
while IFS=: read -r path count; do
    [[ "$count" == 0 ]] && continue
    got["$path"]=$count
done < <(grep -rc "Ordering::SeqCst" "${scope[@]}")

for path in "${!got[@]}"; do
    if [[ -z "${want[$path]:-}" ]]; then
        echo "FAIL: $path has ${got[$path]} SeqCst site(s) but is not in $allow:"
        grep -n "Ordering::SeqCst" "$path"
        fail=1
    elif [[ "${got[$path]}" -ne "${want[$path]}" ]]; then
        echo "FAIL: $path has ${got[$path]} SeqCst site(s); allowlist budgets ${want[$path]}:"
        grep -n "Ordering::SeqCst" "$path"
        fail=1
    fi
done
for path in "${!want[@]}"; do
    if [[ -z "${got[$path]:-}" ]]; then
        echo "FAIL: $path is allowlisted (${want[$path]}) but has no SeqCst sites — prune the entry"
        fail=1
    fi
done

if [[ "$fail" -eq 0 ]]; then
    total=0
    for c in "${got[@]}"; do total=$((total + c)); done
    echo "OK: $total SeqCst site(s) across ${#got[@]} file(s), all within budget"
fi
exit "$fail"
