#!/usr/bin/env bash
# SeqCst budget check for the concurrency core (rust/src/{dhash,lflist,rcu}).
#
# Thin wrapper: the check itself moved into the `dhash-lint` static
# analyzer (rule `seqcst-budget`, rust/src/lint/seqcst.rs), which counts
# `Ordering::SeqCst` on comment-stripped code against the per-file
# budgets in tools/seqcst_allowlist.txt — the allowlist stays the single
# source of truth, and drift in either direction still fails. Run
# `cargo run --release --bin dhash-lint` (no --rule) for the full rule
# set: safety comments, ord annotations, hot-path denylist, wire codes.
set -euo pipefail
cd "$(dirname "$0")/.."

exec cargo run --release --quiet --manifest-path rust/Cargo.toml \
    --bin dhash-lint -- --rule seqcst-budget
