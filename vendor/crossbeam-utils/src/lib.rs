//! Offline vendored subset of the `crossbeam-utils` crate (no crates.io
//! access in the container image): [`CachePadded`], the one item this
//! workspace uses. Swap the path dependency for the registry version when
//! building with network access.

use std::ops::{Deref, DerefMut};

/// Pads and aligns a value to the length of a cache line so neighbouring
/// values never share one (prevents false sharing between per-thread
/// counters). 128 bytes covers the spatial-prefetcher pairing on modern
/// x86_64 and the 128-byte lines of apple-silicon aarch64 — the same
/// conservative choice the real crate makes for those targets.
#[derive(Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for CachePadded<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("CachePadded").field(&self.value).finish()
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        CachePadded::new(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_and_transparent() {
        assert!(std::mem::align_of::<CachePadded<u64>>() >= 128);
        let mut c = CachePadded::new(5u64);
        assert_eq!(*c, 5);
        *c += 1;
        assert_eq!(c.into_inner(), 6);
    }

    #[test]
    fn array_elements_do_not_share_lines() {
        let xs: Vec<CachePadded<u64>> = (0..4u64).map(CachePadded::new).collect();
        let a = &*xs[0] as *const u64 as usize;
        let b = &*xs[1] as *const u64 as usize;
        assert!(b - a >= 128);
    }
}
