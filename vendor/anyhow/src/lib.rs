//! Offline vendored subset of the `anyhow` crate (the container image has
//! no crates.io access). Implements the slice of the public API this
//! workspace uses — [`Error`], [`Result`], [`Context`], [`anyhow!`],
//! [`bail!`] — with the same semantics: a type-erased error with a
//! human-readable context chain. Swap the path dependency for the registry
//! crate when building with network access; no call sites change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A type-erased error: any `std::error::Error + Send + Sync` plus a chain
/// of context messages added by [`Context`].
///
/// Deliberately does **not** implement `std::error::Error` itself (exactly
/// like the real crate) so the blanket `From<E: std::error::Error>` below
/// cannot collide with the core identity `From<T> for T`.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Box::new(MessageError(message.to_string())))
    }

    /// Wrap this error under a new context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error(Box::new(ContextError {
            msg: context.to_string(),
            source: self.0,
        }))
    }

    /// Iterate the error and its sources, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain {
            next: Some(self.0.as_ref()),
        }
    }

    /// The lowest-level source in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cur: &(dyn StdError + 'static) = self.0.as_ref();
        while let Some(next) = cur.source() {
            cur = next;
        }
        cur
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(Box::new(e))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = source {
            write!(f, "\n    {e}")?;
            source = e.source();
        }
        Ok(())
    }
}

/// Iterator over an error's source chain (see [`Error::chain`]).
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

/// Leaf error holding only a message (`anyhow!`, `Option::context`).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context message layered over an underlying error.
struct ContextError {
    msg: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} (caused by: {})", self.msg, self.source)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref())
    }
}

/// Attach context to a `Result` or `Option`, producing `Result<T, Error>`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is not satisfied
/// (crates.io-compatible subset: the message arguments are required).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn from_and_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn ensure_returns_formatted_error() {
        fn inner(x: u32) -> Result<u32> {
            crate::ensure!(x % 2 == 0, "odd input {x}");
            Ok(x / 2)
        }
        assert_eq!(inner(4).unwrap(), 2);
        assert_eq!(inner(3).unwrap_err().to_string(), "odd input 3");
    }

    #[test]
    fn context_chains_and_debug_prints_causes() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(e.chain().count(), 2);
        assert!(format!("{e:?}").contains("Caused by"));
        assert_eq!(e.root_cause().to_string(), "missing");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let e: Result<()> = Err(anyhow!("bottom {}", 1));
        let e = e.with_context(|| format!("layer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "layer 2");
        assert_eq!(e.root_cause().to_string(), "bottom 1");

        let none: Option<u32> = None;
        assert_eq!(none.context("absent").unwrap_err().to_string(), "absent");
        assert_eq!(Some(3u32).context("absent").unwrap(), 3);
    }

    #[test]
    fn bail_returns_error() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged: {flag}");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged: true");
    }
}
