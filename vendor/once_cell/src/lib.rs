//! Offline vendored subset of the `once_cell` crate (no crates.io access
//! in the container image): `sync::Lazy` and `sync::OnceCell`, built on
//! `std::sync::OnceLock`. Same public semantics as the registry crate for
//! the surface this workspace uses; swap the path dependency for the
//! registry version when building with network access.

pub mod sync {
    use std::cell::Cell;
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// Thread-safe cell initialized at most once (`once_cell::sync::OnceCell`).
    pub struct OnceCell<T>(OnceLock<T>);

    impl<T> OnceCell<T> {
        pub const fn new() -> Self {
            OnceCell(OnceLock::new())
        }

        pub fn get(&self) -> Option<&T> {
            self.0.get()
        }

        pub fn set(&self, value: T) -> Result<(), T> {
            self.0.set(value)
        }

        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.0.get_or_init(f)
        }
    }

    impl<T> Default for OnceCell<T> {
        fn default() -> Self {
            Self::new()
        }
    }

    /// A value lazily initialized on first dereference
    /// (`once_cell::sync::Lazy`); usable in `static` items.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: Cell<Option<F>>,
    }

    // SAFETY: `init` is only taken inside `OnceLock::get_or_init`, which
    // serializes the single initialization across threads; afterwards the
    // cell is never touched again.
    unsafe impl<T: Sync + Send, F: Send> Sync for Lazy<T, F> {}

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Lazy {
                cell: OnceLock::new(),
                init: Cell::new(Some(init)),
            }
        }
    }

    impl<T, F: FnOnce() -> T> Lazy<T, F> {
        pub fn force(this: &Self) -> &T {
            this.cell.get_or_init(|| match this.init.take() {
                Some(f) => f(),
                None => panic!("Lazy instance previously poisoned"),
            })
        }
    }

    impl<T, F: FnOnce() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::{Lazy, OnceCell};
    use std::sync::atomic::{AtomicU32, Ordering};

    static COUNT: AtomicU32 = AtomicU32::new(0);
    static LAZY: Lazy<u32> = Lazy::new(|| {
        COUNT.fetch_add(1, Ordering::SeqCst);
        42
    });

    #[test]
    fn lazy_initializes_exactly_once() {
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(std::thread::spawn(|| *LAZY));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 42);
        }
        assert_eq!(*LAZY, 42);
        assert_eq!(COUNT.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn once_cell_set_get() {
        let c: OnceCell<u32> = OnceCell::new();
        assert!(c.get().is_none());
        assert!(c.set(1).is_ok());
        assert_eq!(c.set(2), Err(2));
        assert_eq!(c.get_or_init(|| 9), &1);
    }
}
