"""L2: the collision-analytics graphs, composed from the L1 Pallas
kernels and lowered once by aot.py. Python never runs at serve time —
the Rust coordinator executes the lowered HLO through PJRT.

Two exported graphs:

* ``batch_hash_fn``  — keys -> bucket ids (the batcher's pre-routing).
* ``detector_fn``    — keys -> (chi2, max_load, hist): bucket-skew
  statistics driving the rebuild controller. chi2 across NBINS detector
  bins ~ chi-square(NBINS-1) under a uniform hash; the controller's
  threshold comes from that distribution (see coordinator/detector.rs).
"""

import jax
import jax.numpy as jnp

from .kernels.hash_kernel import batch_hash
from .kernels.hist_kernel import NBINS, bucket_histogram

jax.config.update("jax_enable_x64", True)

# Exported batch size: the coordinator pads/folds its key samples to this.
BATCH = 4096


def batch_hash_fn(keys, seed, nbuckets, kind):
    """keys u64[BATCH], seed/nbuckets/kind u64[1] -> int32[BATCH]."""
    return (batch_hash(keys, seed, nbuckets, kind),)


def detector_fn(keys, seed, nbuckets, kind):
    """Bucket-skew statistics for a key sample.

    Returns (chi2 f32[], max_load i32[], hist i32[NBINS]).
    """
    ids = batch_hash(keys, seed, nbuckets, kind)
    partials = bucket_histogram(ids)
    hist = jnp.sum(partials, axis=0, dtype=jnp.int32)
    expected = jnp.float32(keys.shape[0] / NBINS)
    diff = hist.astype(jnp.float32) - expected
    chi2 = jnp.sum(diff * diff) / expected
    max_load = jnp.max(hist)
    return chi2, max_load, hist


def example_args(batch: int = BATCH):
    """ShapeDtypeStructs for lowering."""
    u64 = jnp.uint64
    return (
        jax.ShapeDtypeStruct((batch,), u64),
        jax.ShapeDtypeStruct((1,), u64),
        jax.ShapeDtypeStruct((1,), u64),
        jax.ShapeDtypeStruct((1,), u64),
    )
