"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla_extension
0.5.1 backing the `xla` crate rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=model.BATCH)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    ex = model.example_args(args.batch)
    artifacts = {
        "batch_hash.hlo.txt": (model.batch_hash_fn, "keys->bucket ids"),
        "detector.hlo.txt": (model.detector_fn, "keys->(chi2,max_load,hist)"),
    }
    from .kernels.hist_kernel import NBINS

    manifest = {
        "batch": args.batch,
        "nbins": NBINS,
        "outputs": {},
    }
    for name, (fn, desc) in artifacts.items():
        text = to_hlo_text(fn, ex)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["outputs"][name] = {"desc": desc, "chars": len(text)}
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
