"""L1 Pallas kernel: per-block bucket histogram via one-hot matmul.

Histogramming is a scatter — hostile to SIMD/MXU hardware. The TPU-shaped
formulation (DESIGN.md §Hardware-Adaptation) recasts it as a dense
matmul: ``ones[1, BLOCK] @ one_hot(ids)[BLOCK, NBINS]``, which maps onto
the MXU systolic array instead of serializing through scalar scatters.
Each grid step emits a partial histogram for its block; the L2 graph sums
the partials (a tiny [nblocks, NBINS] reduction XLA fuses away).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hash_kernel import BLOCK

# Detector granularity: table bucket ids are folded modulo NBINS. 256 bins
# keeps the one-hot tile at BLOCK x 256 f32 = 1 MiB — comfortably in VMEM
# alongside the id tile — while resolving single-bucket flood attacks.
NBINS = 256


def _hist_block_kernel(ids_ref, out_ref):
    ids = ids_ref[...] % NBINS
    # One-hot as f32 so the contraction is an MXU matmul (bf16/f32), then
    # round-trip to i32 counts; BLOCK <= 2^24 so f32 sums are exact.
    onehot = (ids[:, None] == jnp.arange(NBINS, dtype=jnp.int32)[None, :]).astype(jnp.float32)
    partial = jnp.sum(onehot, axis=0)
    out_ref[...] = partial.astype(jnp.int32)[None, :]


def bucket_histogram(ids):
    """Partial histograms of int32 bucket ids folded into NBINS bins.

    Args:
      ids: int32[B], B a multiple of BLOCK.

    Returns:
      int32[B // BLOCK, NBINS] per-block partial histograms.
    """
    (b,) = ids.shape
    assert b % BLOCK == 0, f"batch {b} not a multiple of {BLOCK}"
    nblocks = b // BLOCK
    return pl.pallas_call(
        _hist_block_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, NBINS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, NBINS), jnp.int32),
        interpret=True,
    )(ids)
