"""Pure-jnp/numpy oracles for the Pallas kernels (the build-time
correctness signal: pytest asserts kernel == ref on every sweep)."""

import numpy as np

_MASK = (1 << 64) - 1


def mix64_py(z: int) -> int:
    """Reference splitmix64 finalizer on Python ints (exact)."""
    z = (z + 0x9E3779B97F4A7C15) & _MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return z ^ (z >> 31)


def batch_hash_ref(keys: np.ndarray, seed: int, nbuckets: int, kind: int) -> np.ndarray:
    """Oracle for hash_kernel.batch_hash (Python-int exact arithmetic)."""
    out = np.empty(keys.shape[0], dtype=np.int32)
    for i, k in enumerate(keys.tolist()):
        if kind == 0:
            out[i] = k % nbuckets
        else:
            out[i] = mix64_py(k ^ seed) % nbuckets
    return out


def batch_hash_multi_ref(keys: np.ndarray, shard_ids, shard_params) -> np.ndarray:
    """Oracle for the vectorized multi-shard routing kernel
    (rust ``runtime::Engine::batch_hash_multi``): one composite
    ``(shard << 32) | bucket`` routing id per key, each key hashed with
    its shard's ``(seed, nbuckets, kind)`` from ``shard_params``."""
    assert len(shard_ids) == keys.shape[0]
    out = np.empty(keys.shape[0], dtype=np.int64)
    for i, (k, s) in enumerate(zip(keys.tolist(), list(shard_ids))):
        seed, nbuckets, kind = shard_params[int(s)]
        if kind == 0:
            bucket = k % nbuckets
        else:
            bucket = mix64_py(k ^ seed) % nbuckets
        out[i] = (int(s) << 32) | bucket
    return out


def bucket_histogram_ref(ids: np.ndarray, nbins: int, block: int) -> np.ndarray:
    """Oracle for hist_kernel.bucket_histogram (per-block partials)."""
    b = ids.shape[0]
    nblocks = b // block
    out = np.zeros((nblocks, nbins), dtype=np.int32)
    for blk in range(nblocks):
        chunk = ids[blk * block : (blk + 1) * block] % nbins
        out[blk] = np.bincount(chunk, minlength=nbins).astype(np.int32)
    return out


def detector_ref(keys: np.ndarray, seed: int, nbuckets: int, kind: int, nbins: int):
    """Oracle for the full L2 detector graph.

    Returns (chi2: float, max_load: int, hist: int32[nbins]).
    """
    ids = batch_hash_ref(keys, seed, nbuckets, kind)
    hist = np.bincount(ids % nbins, minlength=nbins).astype(np.int32)
    expected = keys.shape[0] / nbins
    chi2 = float(((hist - expected) ** 2 / expected).sum())
    return chi2, int(hist.max()), hist
