"""L1 Pallas kernel: batched keyed hashing (the splitmix64 finalizer).

This is the compute hot-spot of DHash's control plane: the coordinator
hashes *batches* of sampled keys to estimate bucket-load skew (collision
attacks) and to pre-route batched requests. The mix is bit-for-bit the
same as Rust's ``util::rng::mix64`` (see the pinned-vector tests on both
sides), so the AOT artifact and the Rust data path always agree on bucket
placement.

TPU shaping (DESIGN.md §Hardware-Adaptation): keys stream HBM->VMEM in
``BLOCK``-sized tiles via ``BlockSpec``; the mix is pure element-wise VPU
work on (8,128)-aligned tiles. ``interpret=True`` everywhere — the CPU
PJRT client cannot execute Mosaic custom-calls (see /opt/xla-example).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Keys per grid step. 1024 u64 keys = 8 KiB per tile: far under VMEM and a
# multiple of the (8,128) lane layout once viewed as 8x128.
BLOCK = 1024

def mix64(z):
    """splitmix64 finalizer (Stafford variant 13) on uint64 arrays.

    The constants are materialized *inside* the traced function (Python
    ints + cast) — module-level device arrays would be closure-captured
    constants, which pallas_call rejects.
    """
    c1 = jnp.uint64(0x9E3779B97F4A7C15)
    c2 = jnp.uint64(0xBF58476D1CE4E5B9)
    c3 = jnp.uint64(0x94D049BB133111EB)
    z = (z + c1).astype(jnp.uint64)
    z = ((z ^ (z >> jnp.uint64(30))) * c2).astype(jnp.uint64)
    z = ((z ^ (z >> jnp.uint64(27))) * c3).astype(jnp.uint64)
    return z ^ (z >> jnp.uint64(31))


def _hash_block_kernel(seed_ref, nbuckets_ref, kind_ref, keys_ref, out_ref):
    """One BLOCK of keys -> int32 bucket ids.

    kind == 0: weak modulo placement (``key % nbuckets``), the attackable
    function the paper's motivation section describes.
    kind == 1: seeded placement (``mix64(key ^ seed) % nbuckets``).
    """
    keys = keys_ref[...]
    seed = seed_ref[0]
    nbuckets = nbuckets_ref[0]
    kind = kind_ref[0]
    seeded = mix64(keys ^ seed) % nbuckets
    weak = keys % nbuckets
    ids = jnp.where(kind == jnp.uint64(0), weak, seeded)
    out_ref[...] = ids.astype(jnp.int32)


def batch_hash(keys, seed, nbuckets, kind):
    """Bucket ids for a batch of keys (shape [B], B a multiple of BLOCK).

    Args:
      keys: uint64[B]
      seed: uint64[1]
      nbuckets: uint64[1]  (>= 1)
      kind: uint64[1]      (0 = modulo, 1 = seeded)

    Returns:
      int32[B] bucket ids in [0, nbuckets).
    """
    (b,) = keys.shape
    assert b % BLOCK == 0, f"batch {b} not a multiple of {BLOCK}"
    grid = (b // BLOCK,)
    return pl.pallas_call(
        _hash_block_kernel,
        grid=grid,
        in_specs=[
            # Scalars are broadcast to every grid step.
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            # Key stream: one BLOCK tile per step (HBM->VMEM schedule).
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(seed, nbuckets, kind, keys)
