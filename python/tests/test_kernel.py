"""Kernel-vs-oracle correctness: the CORE build-time signal.

Covers: pinned mix64 vectors (Rust agreement), hash kernel vs exact
Python-int oracle, histogram kernel vs numpy bincount, the composed
detector graph, and hypothesis sweeps over shapes/values.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.hash_kernel import BLOCK, batch_hash, mix64
from compile.kernels.hist_kernel import NBINS, bucket_histogram
from compile.kernels import ref
from compile import model

jax.config.update("jax_enable_x64", True)


# The same vectors pinned in rust/src/util/rng.rs — guarantees the Rust
# data path and the Pallas kernel place keys identically.
PINNED = [
    (0x0, 0xE220A8397B1DCDAF),
    (0x1, 0x910A2DEC89025CC1),
    (0x2, 0x975835DE1C9756CE),
    (0xDEADBEEF, 0x4ADFB90F68C9EB9B),
    (0xFFFFFFFFFFFFFFFF, 0xE4D971771B652C20),
]


def u64(xs):
    return jnp.asarray(xs, dtype=jnp.uint64)


class TestMix64:
    def test_pinned_vectors_jnp(self):
        for x, want in PINNED:
            got = int(mix64(u64([x]))[0])
            assert got == want, f"mix64({x:#x}) = {got:#x}, want {want:#x}"

    def test_pinned_vectors_py(self):
        for x, want in PINNED:
            assert ref.mix64_py(x) == want

    @given(st.integers(min_value=0, max_value=(1 << 64) - 1))
    @settings(max_examples=200, deadline=None)
    def test_jnp_matches_python_int_reference(self, x):
        assert int(mix64(u64([x]))[0]) == ref.mix64_py(x)


class TestBatchHashKernel:
    @pytest.mark.parametrize("kind", [0, 1])
    @pytest.mark.parametrize("nbuckets", [1, 2, 64, 1024, 10_000_019])
    def test_matches_ref(self, kind, nbuckets):
        rng = np.random.default_rng(42)
        keys = rng.integers(0, 1 << 64, size=BLOCK, dtype=np.uint64)
        seed = 0xFEEDFACE
        got = np.asarray(batch_hash(u64(keys), u64([seed]), u64([nbuckets]), u64([kind])))
        want = ref.batch_hash_ref(keys, seed, nbuckets, kind)
        np.testing.assert_array_equal(got, want)
        assert got.max() < nbuckets

    def test_multi_block_grid(self):
        rng = np.random.default_rng(7)
        b = 4 * BLOCK
        keys = rng.integers(0, 1 << 64, size=b, dtype=np.uint64)
        got = np.asarray(batch_hash(u64(keys), u64([1]), u64([97]), u64([1])))
        want = ref.batch_hash_ref(keys, 1, 97, 1)
        np.testing.assert_array_equal(got, want)

    def test_modulo_kind_is_attackable(self):
        nb = 64
        keys = np.arange(5, 5 + 64 * BLOCK, 64, dtype=np.uint64)[:BLOCK]
        ids = np.asarray(batch_hash(u64(keys), u64([0]), u64([nb]), u64([0])))
        assert (ids == 5).all()

    def test_seeded_kind_spreads_attack_keys(self):
        nb = 64
        keys = np.arange(5, 5 + 64 * BLOCK, 64, dtype=np.uint64)[:BLOCK]
        ids = np.asarray(batch_hash(u64(keys), u64([9]), u64([nb]), u64([1])))
        counts = np.bincount(ids, minlength=nb)
        assert counts.max() < BLOCK // 8  # spread out, no flood bucket

    @given(
        nblocks=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=(1 << 64) - 1),
        nbuckets=st.integers(min_value=1, max_value=(1 << 31) - 1),  # int32 id range
        kind=st.integers(min_value=0, max_value=1),
        data_seed=st.integers(min_value=0, max_value=2**31),
    )
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_sweep(self, nblocks, seed, nbuckets, kind, data_seed):
        rng = np.random.default_rng(data_seed)
        keys = rng.integers(0, 1 << 64, size=nblocks * BLOCK, dtype=np.uint64)
        got = np.asarray(batch_hash(u64(keys), u64([seed]), u64([nbuckets]), u64([kind])))
        want = ref.batch_hash_ref(keys, seed, nbuckets, kind)
        np.testing.assert_array_equal(got, want)


class TestHistogramKernel:
    def test_matches_ref_uniform(self):
        rng = np.random.default_rng(3)
        ids = rng.integers(0, 4096, size=2 * BLOCK, dtype=np.int32)
        got = np.asarray(bucket_histogram(jnp.asarray(ids)))
        want = ref.bucket_histogram_ref(ids, NBINS, BLOCK)
        np.testing.assert_array_equal(got, want)
        assert got.sum() == 2 * BLOCK

    def test_flood_concentrates(self):
        ids = np.full(BLOCK, 37, dtype=np.int32)
        got = np.asarray(bucket_histogram(jnp.asarray(ids)))
        assert got[0, 37] == BLOCK
        assert got.sum() == BLOCK

    @given(
        nblocks=st.integers(min_value=1, max_value=3),
        hi=st.integers(min_value=1, max_value=1 << 20),
        data_seed=st.integers(min_value=0, max_value=2**31),
    )
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_sweep(self, nblocks, hi, data_seed):
        rng = np.random.default_rng(data_seed)
        ids = rng.integers(0, hi, size=nblocks * BLOCK, dtype=np.int32)
        got = np.asarray(bucket_histogram(jnp.asarray(ids)))
        want = ref.bucket_histogram_ref(ids, NBINS, BLOCK)
        np.testing.assert_array_equal(got, want)


class TestDetectorGraph:
    def run_detector(self, keys, seed, nbuckets, kind):
        chi2, max_load, hist = jax.jit(model.detector_fn)(
            u64(keys), u64([seed]), u64([nbuckets]), u64([kind])
        )
        return float(chi2), int(max_load), np.asarray(hist)

    def test_matches_ref(self):
        rng = np.random.default_rng(11)
        keys = rng.integers(0, 1 << 64, size=model.BATCH, dtype=np.uint64)
        got = self.run_detector(keys, 5, 1024, 1)
        want = ref.detector_ref(keys, 5, 1024, 1, NBINS)
        assert got[1] == want[1]
        np.testing.assert_array_equal(got[2], want[2])
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5)

    def test_uniform_low_chi2_attack_high_chi2(self):
        rng = np.random.default_rng(12)
        uniform = rng.integers(0, 1 << 64, size=model.BATCH, dtype=np.uint64)
        chi2_u, _, _ = self.run_detector(uniform, 1, 4096, 1)
        # Under H0, E[chi2] = NBINS - 1 = 255; 2x that is a generous bound.
        assert chi2_u < 2 * (NBINS - 1), f"uniform chi2 {chi2_u}"
        # Attack: all keys in one bucket under modulo hashing.
        attack = np.arange(3, 3 + 4096 * model.BATCH, 4096, dtype=np.uint64)[: model.BATCH]
        chi2_a, max_a, _ = self.run_detector(attack, 1, 4096, 0)
        assert chi2_a > 100 * (NBINS - 1), f"attack chi2 {chi2_a}"
        assert max_a == model.BATCH

    def test_detector_batch_is_block_multiple(self):
        assert model.BATCH % BLOCK == 0


class TestAotLowering:
    def test_hlo_text_exports(self, tmp_path):
        from compile.aot import to_hlo_text

        ex = model.example_args()
        for fn in (model.batch_hash_fn, model.detector_fn):
            text = to_hlo_text(fn, ex)
            assert "HloModule" in text
            assert len(text) > 1000
